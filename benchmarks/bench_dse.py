"""Cross-problem DSE sweep benchmark: `pack_sweep` vs the serial loop.

The paper's section-2.3 use-case at fleet scale: every (accelerator x
device x seed) candidate of a design-space exploration needs a packed OCM
estimate.  Tables:

* ``dse_throughput`` — aggregate candidates/sec of one batched
  ``pack_sweep`` call vs the serial per-candidate ``pack`` loop on the
  Table-1 accelerators across the ZU7EV and U50 inventories, at an
  identical per-candidate iteration budget.  Because every candidate in
  the batch consumes its own RNG stream, the per-candidate costs are
  **bit-identical** to the serial loop's (the ``costs_match`` column) —
  the sweep must be >= 5x on aggregate candidates/sec while returning
  exactly the same packings.
* ``dse_candidates`` — the per-candidate report of the batched sweep
  (cost, efficiency, overflow, Pareto membership), i.e. what a DSE outer
  loop would consume.
* ``dse_cache`` — the fingerprint cache: re-sweeping the same fleet is
  served entirely from the cache (candidates/sec goes effectively
  infinite; the row reports the measured rate and hit count).
"""
from __future__ import annotations

import time

import repro.core as c

from .common import emit


def _fleet(quick: bool, smoke: bool = False):
    names = (
        ["CNV-W1A1", "CNV-W2A2"]
        if smoke
        else ["CNV-W1A1", "CNV-W2A2", "Tincy-YOLO", "RN50-W1A2"]
        if quick
        else list(c.ACCELERATORS)
    )
    devices = ["ZU7EV", "U50"]
    n_seeds = 2
    probs = [
        c.get_problem(name, device=dev) for name in names for dev in devices
    ] * n_seeds
    seeds = [s for s in range(n_seeds) for _ in range(len(names) * len(devices))]
    return probs, seeds


def run(quick: bool = False, n_chains: int = 8, iterations: int | None = None,
        smoke: bool = False):
    if smoke:
        n_chains = min(n_chains, 4)
    probs, seeds = _fleet(quick, smoke)
    iters = (
        iterations if iterations is not None
        else (80 if smoke else 1200 if quick else 2500)
    )
    kw = dict(
        max_seconds=1e9, patience=10**9, max_iterations=iters,
        backend="python", n_chains=n_chains,
    )
    warm = {**kw, "max_iterations": 50}

    # ------------------------------------------------------------ throughput
    # Equal per-candidate iteration budgets; warmup runs first so one-time
    # NFD/codec setup does not skew either side's clock.
    c.pack_sweep(probs[:2], "sa-s", seeds=seeds[:2], **warm)
    for p, s in zip(probs[:2], seeds[:2]):
        c.pack(p, "sa-s", seed=s, **warm)
    t0 = time.perf_counter()
    serial = [c.pack(p, "sa-s", seed=s, **kw) for p, s in zip(probs, seeds)]
    t_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep = c.pack_sweep(probs, "sa-s", seeds=seeds, **kw)
    t_batch = time.perf_counter() - t0
    costs_match = [r.cost for r in sweep.results] == [r.cost for r in serial]
    header = [
        "mode", "candidates", "groups", "n_chains", "iters_per_candidate",
        "wall_s", "candidates_per_sec", "speedup_vs_serial", "costs_match",
    ]
    rows = [
        ["serial", len(probs), len(probs), n_chains, iters,
         round(t_serial, 2), round(len(probs) / t_serial, 2), 1.0, True],
        ["pack_sweep", len(probs), sweep.n_groups, n_chains, iters,
         round(t_batch, 2), round(len(probs) / t_batch, 2),
         round(t_serial / t_batch, 2), costs_match],
    ]
    emit("dse_throughput", header, rows)

    # ------------------------------------------------------------ candidates
    pareto = set(sweep.pareto_indices())
    header2 = [
        "candidate", "seed", "buffers", "baseline", "cost", "efficiency_pct",
        "overflow_units", "pareto",
    ]
    rows2 = [
        [prob.name, s, prob.n, prob.baseline_cost(), r.cost,
         round(r.efficiency * 100, 1), r.solution.inventory_overflow(),
         i in pareto]
        for i, (prob, s, r) in enumerate(zip(probs, seeds, sweep.results))
    ]
    emit("dse_candidates", header2, rows2)

    # ----------------------------------------------------------------- cache
    cache_iters = 40 if smoke else 200 if quick else 400
    cache: dict = {}
    t0 = time.perf_counter()
    first = c.pack_sweep(probs, "sa-s", seeds=seeds, cache=cache,
                         **{**kw, "max_iterations": cache_iters})
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    second = c.pack_sweep(probs, "sa-s", seeds=seeds, cache=cache,
                          **{**kw, "max_iterations": cache_iters})
    t_second = time.perf_counter() - t0
    header3 = ["sweep", "wall_s", "candidates_per_sec", "solved", "cache_hits"]
    rows3 = [
        ["cold", round(t_first, 3), round(len(probs) / t_first, 1),
         first.n_solved, first.cache_hits],
        ["warm", round(t_second, 4), round(len(probs) / max(t_second, 1e-9), 1),
         second.n_solved, second.cache_hits],
    ]
    emit("dse_cache", header3, rows3)
    assert second.n_solved == 0, "warm sweep must be served from the cache"
    return rows, rows2, rows3
