"""Evolution-engine benchmark: the incremental + batched hot path vs the
seed's from-scratch scalar evaluation.

GA tables (``run``):

* ``engine_throughput`` — GA-NFD generations/sec per accelerator and
  backend at an identical generation budget.  Backends are bit-identical
  for a fixed seed, so the ``cost`` column doubles as a parity check
  (``cost_match`` vs the legacy engine).  Generation rate is measured
  between a short warm run and a long run, cancelling population-init and
  JIT-compile time out of the quotient.
* ``engine_convergence`` — equal-wall-clock quality: final BRAM cost and
  time-to-within-1%-of-best for the legacy engine, the new engine, and the
  island portfolio under the same budget.

Heterogeneous OCM table (``run_hetero``):

* ``engine_hetero`` — the same workload packed (a) BRAM18-only, as the
  paper does, and (b) onto a real device inventory (Alveo U50: 2688
  BRAM18 + 640 URAM288, the regime where deep ResNets overflow BRAM
  alone).  Both packings are scored under the device inventory with the
  engines' unit-weighted overflow penalty: the heterogeneous run must
  beat the BRAM18-only packing's penalized cost (typically by being
  feasible at all — the point of arXiv:2011.07317's mixed BRAM+URAM
  mapping).

SA tables (``run_sa``):

* ``sa_throughput`` — aggregate chain-iterations/sec of the vectorized
  multi-chain SA-S engine per backend vs the scalar ``legacy`` loop, again
  measured between two timed runs; the ``cost`` column shows the final
  best cost at the identical wall-clock budget (the batched engine must be
  equal-or-better while being >= 10x on RN152-W1A2).
* ``sa_cost_vs_time`` — the best-cost-so-far trace of each long run, for
  cost-vs-wall-time convergence plots.

Portfolio table (``run_portfolio``):

* ``portfolio_throughput`` — the fleet-native island portfolio vs the
  legacy thread-pool portfolio at an identical wall budget and island
  lineup: aggregate island iterations/sec (SA steps + GA generations per
  wall second, summed over islands) and the final cost.  The fleet engine
  must be >= 2x aggregate throughput at equal-or-better cost on
  RN152-W1A2 — and, unlike the thread version, it is bit-reproducible.

Every ``run*`` entry point takes ``smoke=True`` (used by
``benchmarks/run.py --smoke``) to finish in a few seconds on a tiny
problem — an execution check, not a measurement.
"""
from __future__ import annotations

import time

import repro.core as c
from repro.core.ga import GeneticPacker
from repro.core.portfolio import pack_portfolio_threads
from repro.core.sa import SimulatedAnnealingPacker

from .common import BUDGETS, emit

THROUGHPUT_BACKENDS = ("legacy", "python", "ref")


def _timed_pack(prob, hp, backend, seconds=None, gens=None, seed=0):
    packer = GeneticPacker(
        backend=backend,
        seed=seed,
        max_generations=gens if gens is not None else 10**9,
        max_seconds=seconds if seconds is not None else 1e9,
        patience=10**9,
        p_adm_w=hp.get("p_adm_w", 0.0),
        p_adm_h=hp.get("p_adm_h", 0.1),
        n_pop=hp.get("n_pop", 50),
        n_tour=hp.get("n_tour", 5),
        p_mut=hp.get("p_mut", 0.4),
    )
    t0 = time.perf_counter()
    result = packer.pack(prob)
    return result, time.perf_counter() - t0


def run(accelerators=None, gens=None, budgets=None, quick=False, smoke=False):
    if accelerators is None:
        accelerators = (
            ["CNV-W1A1"]
            if smoke
            else ["CNV-W1A1", "RN152-W1A2"]
            if quick
            else ["CNV-W1A1", "Tincy-YOLO", "DoReFaNet", "RN50-W1A2", "RN152-W1A2"]
        )
    t_warm, t_full = (0.15, 0.5) if smoke else (0.4, 1.6) if quick else (1.0, 5.0)
    g_parity = gens if gens is not None else (5 if smoke else 25 if quick else 110)
    budgets = budgets or BUDGETS

    # ---------------------------------------------------------- throughput
    # Two timed runs per backend; the generation rate is taken between them,
    # cancelling population-init and JIT-compile time out of the quotient.
    # The parity columns come from a third run at a fixed generation count:
    # all backends must land on the exact same cost for the same seed.
    header = [
        "accelerator", "backend", "gens_per_sec", "speedup_vs_legacy",
        "cost", "cost_match",
    ]
    rows = []
    for name in accelerators:
        prob = c.get_problem(name)
        hp = c.hyperparams(name)
        legacy_gps = None
        legacy_cost = None
        for backend in THROUGHPUT_BACKENDS:
            r_warm, dt_warm = _timed_pack(prob, hp, backend, seconds=t_warm)
            r_full, dt_full = _timed_pack(prob, hp, backend, seconds=t_full)
            gps = (r_full.iterations - r_warm.iterations) / max(
                dt_full - dt_warm, 1e-9
            )
            parity, _ = _timed_pack(prob, hp, backend, gens=g_parity)
            if backend == "legacy":
                legacy_gps, legacy_cost = gps, parity.cost
            rows.append(
                [
                    name,
                    backend,
                    round(gps, 1),
                    round(gps / legacy_gps, 2),
                    parity.cost,
                    parity.cost == legacy_cost,
                ]
            )
    emit("engine_throughput", header, rows)

    # --------------------------------------------------------- convergence
    header2 = ["accelerator", "engine", "cost", "t_to_1pct_s", "budget_s"]
    rows2 = []
    for name in accelerators:
        prob = c.get_problem(name)
        hp = c.hyperparams(name)
        budget = 1 if smoke else max(2, budgets[name] // (4 if quick else 2))
        for engine, backend in (("ga-nfd-legacy", "legacy"), ("ga-nfd", "auto")):
            r = c.pack(prob, "ga-nfd", seed=0, max_seconds=budget, backend=backend, **hp)
            r.solution.validate()
            rows2.append([name, engine, r.cost, round(r.time_to_within(0.01), 2), budget])
        r = c.pack_portfolio(
            prob, n_islands=2 if (quick or smoke) else 4, seed=0,
            max_seconds=budget, **hp
        )
        r.solution.validate()
        rows2.append(
            [name, "portfolio", r.cost, round(r.time_to_within(0.01), 2), budget]
        )
    emit("engine_convergence", header2, rows2)
    return rows, rows2


# ------------------------------------------------------------ heterogeneous
def run_hetero(accelerators=None, device="U50", quick=False, budget_s=None,
               smoke=False):
    """BRAM18-only vs heterogeneous device packing of the same workloads.

    Costs are in the device's inventory units (1 unit = 1 BRAM18 worth of
    capacity; 1 URAM288 = 16 units), so the two scenarios are directly
    comparable; ``penalized`` adds the engines' inventory-overflow penalty,
    the quantity the heterogeneous packer actually optimizes.
    """
    from repro.core.problem import Solution

    if accelerators is None:
        accelerators = (
            ["CNV-W1A1"]
            if smoke
            else ["CNV-W1A1", "RN152-W1A2"]
            if quick
            else ["RN50-W1A2", "RN101-W1A2", "RN152-W1A2"]
        )
    if budget_s is not None:
        budget = budget_s
    else:
        budget = 0.5 if smoke else 3.0 if quick else 10.0
    header = [
        "accelerator", "device", "scenario", "cost_units", "overflow_units",
        "penalized", "efficiency_pct", "feasible", "used_bram18", "used_uram288",
    ]
    rows = []
    for name in accelerators:
        hp = c.hyperparams(name)
        prob_dev = c.get_problem(name, device=device)
        # (a) the paper's homogeneous packing, scored on the device
        r18 = c.pack(
            c.get_problem(name), "ga-nfd", seed=0, max_seconds=budget, **hp
        )
        sol18 = Solution(prob_dev, r18.solution.bins)  # all bins on BRAM18
        # (b) the heterogeneous packer on the device inventory
        rdev = c.pack(prob_dev, "ga-nfd", seed=0, max_seconds=budget, **hp)
        rdev.solution.validate()
        # score both scenarios with the penalty the packer actually used
        lam = rdev.params["inventory_penalty"]
        for scenario, sol in (("bram18-only", sol18), ("hetero", rdev.solution)):
            cost = sol.cost()
            ovf = sol.inventory_overflow()
            used = sol.used_primitives()
            rows.append(
                [
                    name,
                    device,
                    scenario,
                    cost,
                    ovf,
                    round(cost + lam * ovf, 1),
                    round(sol.efficiency() * 100, 1),
                    ovf == 0,
                    int(used[0]),
                    int(used[1]) if len(used) > 1 else 0,
                ]
            )
    emit("engine_hetero", header, rows)
    return rows


# --------------------------------------------------------------------- SA
def _timed_sa(prob, backend, n_chains, seconds, seed=0):
    packer = SimulatedAnnealingPacker(
        perturbation="swap",
        backend=backend,
        n_chains=n_chains,
        seed=seed,
        max_seconds=seconds,
        max_iterations=10**9,
        patience=10**9,
    )
    t0 = time.perf_counter()
    result = packer.pack(prob)
    return result, time.perf_counter() - t0


def run_sa(accelerators=None, quick=False, n_chains=32, smoke=False):
    """SA-S engine: aggregate chain-iterations/sec + cost-vs-time traces.

    Rates are taken between a short warm run and a long run (cancelling
    chain-init and jit/interpret warmup); ``legacy`` is the scalar loop
    with its single chain, the batched backends run ``n_chains`` chains.
    """
    if smoke:
        n_chains = min(n_chains, 4)
    if accelerators is None:
        accelerators = (
            ["CNV-W1A1"]
            if smoke
            else ["CNV-W1A1", "RN152-W1A2"]
            if quick
            else ["CNV-W1A1", "Tincy-YOLO", "RN50-W1A2", "RN152-W1A2"]
        )
    t_warm, t_full = (0.15, 0.5) if smoke else (0.5, 2.0) if quick else (1.0, 5.0)
    header = [
        "accelerator", "backend", "n_chains", "chain_iters_per_sec",
        "speedup_vs_legacy", "cost",
    ]
    rows = []
    curve_rows = []
    for name in accelerators:
        prob = c.get_problem(name)
        legacy_ips = None
        for backend in THROUGHPUT_BACKENDS:
            chains = 1 if backend == "legacy" else n_chains
            r_warm, dt_warm = _timed_sa(prob, backend, chains, t_warm)
            r_full, dt_full = _timed_sa(prob, backend, chains, t_full)
            ips = (r_full.iterations - r_warm.iterations) / max(
                dt_full - dt_warm, 1e-9
            )
            r_full.solution.validate()
            if backend == "legacy":
                legacy_ips = ips
            rows.append(
                [
                    name,
                    backend,
                    chains,
                    round(ips),
                    round(ips / legacy_ips, 2),
                    r_full.cost,
                ]
            )
            curve_rows.extend(
                [name, backend, round(t, 4), cost] for t, cost in r_full.trace
            )
            # the trace holds improvements only; close every curve at the
            # shared wall-clock budget so backends plot to the same endpoint
            curve_rows.append(
                [name, backend, round(r_full.wall_time_s, 4), r_full.cost]
            )
    emit("sa_throughput", header, rows)
    emit("sa_cost_vs_time", ["accelerator", "backend", "t_s", "best_cost"],
         curve_rows)
    return rows, curve_rows


# -------------------------------------------------------------- portfolio
def run_portfolio(accelerator=None, quick=False, smoke=False, seed=0,
                  n_islands=4, sa_chains=8, budget_s=None):
    """Fleet-native island portfolio vs the legacy thread-pool portfolio.

    Identical island lineup and wall budget per scenario; the metric is
    *aggregate island iterations/sec* — SA chain-iterations plus GA
    generations summed over every island, divided by the run's wall time.

    The headline ``sa-fleet`` scenario runs K multi-chain ``sa-s`` islands:
    the thread pool runs K batched annealers in K GIL-sharing threads,
    while the fleet engine folds them into ONE `_anneal_block` array
    program of ``K x sa_chains`` problem-major rows — same-problem
    replication through the cross-problem fleet core, which amortizes the
    fixed per-step overhead K ways.  That scenario must clear >= 2x the
    thread pool's aggregate throughput on RN152-W1A2 at an equal-or-better
    final cost — while additionally being bit-reproducible (the thread
    version's wall-clock rounds depend on machine speed).

    The lineup *matrix* covers every engine-family balance: ``mixed`` (the
    default GA+SA+SA-NFD lineup), ``ga-heavy`` and ``scalar-heavy`` stress
    the concurrent barrier scheduler — per-family barrier strides plus the
    side-lane thread pool (docs/DESIGN.md section 13) must keep the fleet's
    ``speedup_vs_threads`` >= 1.0 on every lineup (the ISSUE-7 acceptance
    gate; ``tools/portfolio_gate.py`` enforces the mixed lineup in CI).
    """
    name = accelerator or ("CNV-W1A1" if smoke else "RN152-W1A2")
    budget = budget_s if budget_s is not None else (
        1.0 if smoke else 4.0 if quick else 12.0
    )
    prob = c.get_problem(name)
    hp = c.hyperparams(name)
    header = [
        "accelerator", "scenario", "engine", "islands", "budget_s",
        "island_iters", "agg_iters_per_sec", "speedup_vs_threads", "cost",
        "cost_delta_vs_threads",
    ]
    rows = []
    for scenario, algorithms in (
        ("sa-fleet", ("sa-s",)),
        ("mixed", ("ga-nfd", "sa-s", "sa-nfd")),
        ("ga-heavy", ("ga-nfd", "ga-nfd", "ga-nfd", "sa-s")),
        ("scalar-heavy", ("sa-nfd", "sa-nfd", "sa-nfd", "sa-s")),
    ):
        kw = dict(
            n_islands=n_islands, algorithms=algorithms, seed=seed,
            max_seconds=budget, sa_chains=sa_chains, **hp,
        )
        # thread engine first: its wall-clock rounds are the baseline
        rt = pack_portfolio_threads(prob, **kw)
        rt.solution.validate()
        rf = c.pack_portfolio(prob, **kw)
        rf.solution.validate()
        ips_t = rt.iterations / max(rt.wall_time_s, 1e-9)
        for label, r in (("threads", rt), ("fleet", rf)):
            ips = r.iterations / max(r.wall_time_s, 1e-9)
            rows.append([
                name, scenario, label, n_islands, budget, r.iterations,
                round(ips), round(ips / ips_t, 2), r.cost, r.cost - rt.cost,
            ])
    emit("portfolio_throughput", header, rows)
    return rows
