"""Paper Figs. 4/5: GA-NFD population-size study on ResNet-50."""
from __future__ import annotations

import json

import numpy as np

import repro.core as c

from .common import OUT_DIR, emit

POPS = (5, 25, 50, 150)


def run(budget_s: float = 25.0, seeds=(0, 1)):
    prob = c.get_problem("RN50-W1A2")
    hp = c.hyperparams("RN50-W1A2")
    header = ["population", "bram_best", "bram_mean", "t_converge_mean_s"]
    rows = []
    for pop in POPS:
        costs, times = [], []
        for seed in seeds:
            hp2 = dict(hp)
            hp2["n_pop"] = pop
            r = c.pack(prob, "ga-nfd", seed=seed, max_seconds=budget_s, **hp2)
            costs.append(r.cost)
            times.append(r.time_to_within(0.01))
        rows.append(
            [pop, int(min(costs)), float(np.mean(costs)),
             round(float(np.mean(times)), 2)]
        )
    emit("fig45_population_size", header, rows)
    record = {
        "accelerator": "RN50-W1A2",
        "budget_s": budget_s,
        "seeds": list(seeds),
        "rows": [dict(zip(header, row)) for row in rows],
    }
    (OUT_DIR / "BENCH_fig45.json").write_text(json.dumps(record, indent=2))
    return rows
