"""Kernel micro-benchmarks: pallas (interpret) vs jnp ref, us/call.

On this CPU host the pallas interpreter is the *correctness* path; the
numbers demonstrate the harness (real speed requires the TPU backend).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import BRAM18_MODES
from repro.kernels.binpack_fitness.kernel import binpack_fitness_pallas
from repro.kernels.binpack_fitness.ref import binpack_fitness_ref
from repro.kernels.packed_gather.kernel import packed_gather_matvec
from repro.kernels.packed_gather.ref import packed_gather_ref

from .common import emit


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    for p, nb in [(50, 1000), (75, 2500)]:
        w = jnp.asarray(rng.integers(1, 80, (p, nb)), jnp.int32)
        h = jnp.asarray(rng.integers(1, 70_000, (p, nb)), jnp.int32)
        us_pl = _time(lambda a, b: binpack_fitness_pallas(a, b, BRAM18_MODES, True), w, h)
        us_ref = _time(jax.jit(lambda a, b: binpack_fitness_ref(a, b, BRAM18_MODES)), w, h)
        rows.append([f"binpack_fitness_{p}x{nb}", round(us_pl, 1), round(us_ref, 1)])
    for r, c, n in [(512, 512, 4), (2048, 1024, 4)]:
        bank = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        seg = jnp.asarray(rng.integers(0, n, r), jnp.int32)
        us_pl = _time(lambda b, xx, s: packed_gather_matvec(b, xx, s, interpret=True), bank, x, seg)
        us_ref = _time(jax.jit(packed_gather_ref), bank, x, seg)
        rows.append([f"packed_gather_{r}x{c}x{n}", round(us_pl, 1), round(us_ref, 1)])
    emit("kernels_microbench", ["name", "pallas_interpret_us", "jnp_ref_us"], rows)
    return rows
