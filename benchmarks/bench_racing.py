"""Self-tuning portfolio deliverable: auto racing vs the default lineup.

Runs ``pack_portfolio(auto=True)`` (successive-halving over an SA config
grid) against the default same-size lineup at EQUAL total iteration
budget — the race ledger is left at its default, which anchors it to
exactly the work the default lineup consumes, and the SA-only lineups
keep the ledger in raw chain-step units so "equal" is exact, not
work-unit-approximate.  Everything is iteration-budgeted and
``backend="python"`` so the numbers are machine-independent.

Emits ``BENCH_racing.json`` with the hard flag ``auto_cost_le_default``;
outside smoke mode the flag is asserted — the bench FAILS if the
self-tuned portfolio loses to the lineup it replaces on any accelerator.
"""
from __future__ import annotations

import json
import time

import repro.core as c

from .common import OUT_DIR, emit

# chain counts held equal so every config costs the same per barrier and
# the ledger stays in raw chain-step units; the race tunes the ladder and
# temperature schedule
GRID = (
    ("sa-s", {"n_chains": 4}),
    ("sa-s", {"n_chains": 4, "ladder_max": 8.0}),
    ("sa-s", {"n_chains": 4, "sa_t0": 60.0, "sa_rc": 0.5}),
    ("sa-s", {"n_chains": 4, "sa_t0": 10.0, "sa_rc": 2.0}),
)


def run(quick: bool = False, smoke: bool = False):
    if smoke:
        accels, iters = ["CNV-W1A1"], 64
    elif quick:
        accels, iters = ["CNV-W1A1", "CNV-W2A2"], 512
    else:
        accels, iters = ["CNV-W1A1", "CNV-W2A2", "Tincy-YOLO", "RN50-W1A2"], 2048

    kw = dict(
        seed=0, backend="python", max_seconds=1e9, patience=10**9,
        migration_every=32, sa_chains=4, n_islands=4, algorithms=("sa-s",),
        max_iterations=iters,
    )
    header = ["accelerator", "budget", "spent", "auto_cost", "default_cost",
              "auto_iters", "default_iters", "auto_s", "default_s"]
    rows, details = [], []
    for name in accels:
        prob = c.get_problem(name)
        t0 = time.perf_counter()
        auto = c.pack_portfolio(prob, auto=True, race_grid=list(GRID), **kw)
        t_auto = time.perf_counter() - t0
        t0 = time.perf_counter()
        default = c.pack_portfolio(prob, **kw)
        t_default = time.perf_counter() - t0
        race = auto.params["race"]
        assert race["spent"] <= race["budget"], name  # ledger is a hard cap
        rows.append([
            name, race["budget"], race["spent"], auto.cost, default.cost,
            auto.iterations, default.iterations,
            round(t_auto, 2), round(t_default, 2),
        ])
        details.append({
            "accelerator": name,
            "budget": race["budget"],
            "spent": race["spent"],
            "auto_cost": auto.cost,
            "default_cost": default.cost,
            "auto_iterations": auto.iterations,
            "default_iterations": default.iterations,
            "survivors": race["survivors"],
            "eliminated": race["eliminated"],
        })
    emit("racing_auto_vs_default", header, rows)
    flag = all(d["auto_cost"] <= d["default_cost"] for d in details)
    record = {
        "mode": "smoke" if smoke else ("quick" if quick else "full"),
        "max_iterations": iters,
        "grid": [[a, h] for a, h in GRID],
        "results": details,
        "auto_cost_le_default": flag,
    }
    (OUT_DIR / "BENCH_racing.json").write_text(json.dumps(record, indent=2))
    if not smoke:
        # the deliverable, enforced: auto-tuning must not lose at equal budget
        assert flag, f"auto lost to the default lineup: {details}"
    return rows
