"""Deliverable (g): per-(arch x shape x mesh) roofline table from the
compiled dry-run artifacts (experiments/dryrun/*.json)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run():
    header = [
        "arch", "shape", "pods", "compute_s", "memory_s", "collective_s",
        "dominant", "useful_flops_ratio", "hbm_args_gb_per_dev",
    ]
    rows = []
    if not DRYRUN.exists():
        print("roofline: run `python -m repro.launch.dryrun --all` first")
        return rows
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            rows.append([r.get("arch"), r.get("shape"),
                         2 if r.get("multi_pod") else 1, "FAIL", "", "", "", "", ""])
            continue
        t = r["roofline"]
        rows.append([
            r["arch"], r["shape"], 2 if r["multi_pod"] else 1,
            f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
            f"{t['collective_s']:.3e}", t["dominant"],
            round(r.get("useful_flops_ratio", 0.0), 3),
            round(r["memory"]["argument_bytes"] / 2**30, 3),
        ])
    emit("roofline_table", header, rows)
    return rows
