"""Beyond-paper: NFD sequence packing vs greedy/no-packing in the data path."""
from __future__ import annotations

import time

import numpy as np

from repro.data.packing import pack_documents, packing_efficiency

from .common import emit


def run(seq_len: int = 4096, n_docs: int = 2000, seed: int = 0):
    rng = np.random.default_rng(seed)
    lengths = np.clip(
        rng.lognormal(np.log(700), 0.8, n_docs).astype(int), 16, seq_len
    ).tolist()
    header = ["strategy", "sequences", "token_efficiency_pct", "time_s"]
    rows = []
    # no packing: one doc per sequence
    rows.append(
        ["one-doc-per-seq", n_docs,
         round(sum(lengths) / (n_docs * seq_len) * 100, 2), 0.0]
    )
    for algo in ("next-fit", "ffd", "nfd", "ga-nfd"):
        t0 = time.perf_counter()
        seqs = pack_documents(lengths, seq_len, max_docs_per_seq=16, algorithm=algo)
        dt = time.perf_counter() - t0
        rows.append(
            [algo, len(seqs),
             round(packing_efficiency(seqs, lengths, seq_len) * 100, 2),
             round(dt, 2)]
        )
    emit("seqpack_efficiency", header, rows)
    return rows
