"""Service-level load benchmark: sustained rps + tail latency, cold vs warm.

Two passes of the same seeded Poisson/Zipf workload through one
``PackingService`` over a fresh store dir:

* **cold** — empty store, every unique task costs a solve (micro-batched
  on the single-dispatch lane); arrivals are offered faster than the lane
  can drain so the measured rps is the service's sustained capacity, not
  the generator's;
* **warm** — identical workload replayed, all answers from the in-memory
  cache / result store.

Emits ``serve_latency.csv`` (per-request records, both phases) and
``benchmarks/out/BENCH_serve.json`` with rps, p50/p99, batch occupancy,
the warm/cold throughput ratio, and a **hard bit-parity flag**: every
unique task is replayed through standalone ``pack()`` and bit-compared —
an assert, not a report field, in every mode.  The warm >= 10x cold
throughput gate is asserted outside ``--smoke`` (smoke's workload is too
small for a stable ratio, though in practice it clears 10x there too).
"""
from __future__ import annotations

import asyncio
import json
import tempfile

from repro.serve import (
    PackingService,
    make_problems,
    make_workload,
    run_traffic,
    verify_parity,
)

from .common import OUT_DIR, emit

# deterministic engines: iteration budgets drive termination (DESIGN.md §12)
_KW = dict(backend="python", max_seconds=1e9, patience=10**9, n_chains=4)


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        n_requests, n_problems, max_iterations = 24, 4, 60
    elif quick:
        n_requests, n_problems, max_iterations = 120, 8, 150
    else:
        n_requests, n_problems, max_iterations = 400, 16, 300

    problems = make_problems(n_problems, seed=1, hetero=True)
    workload = make_workload(
        n_requests, n_problems, rate_hz=5000.0, zipf_a=1.2, n_seeds=2, seed=0,
    )

    async def drive(store_dir):
        async with PackingService(
            "sa-s", store_dir=store_dir, max_batch=8, max_wait_ms=5.0,
            max_queue=64, max_iterations=max_iterations, **_KW,
        ) as svc:
            cold = await run_traffic(svc, problems, workload, concurrency=32)
            cold_stats = svc.stats()
            warm = await run_traffic(svc, problems, workload, concurrency=32)
            warm_stats = svc.stats()
            parity = verify_parity(svc, problems, workload)
            return cold, cold_stats, warm, warm_stats, parity

    with tempfile.TemporaryDirectory() as store_dir:
        cold, cold_stats, warm, warm_stats, parity = asyncio.run(
            drive(store_dir)
        )

    # warm pass counters = totals minus what the cold pass already consumed
    warm_solved = warm_stats["solved"] - cold_stats["solved"]
    ratio = warm["rps"] / cold["rps"] if cold["rps"] else 0.0

    rows = [
        [phase, r["i"], f'{r["arrival_s"]:.6f}', r["prob_idx"], r["seed"],
         f'{r["latency_s"]:.6f}', r["cost"]]
        for phase, out in (("cold", cold), ("warm", warm))
        for r in out["records"]
    ]
    emit("serve_latency",
         ["phase", "i", "arrival_s", "prob_idx", "seed", "latency_s", "cost"],
         rows)

    record = {
        "bench": "serve",
        "mode": "smoke" if smoke else ("quick" if quick else "full"),
        "requests": n_requests,
        "problems": n_problems,
        "unique_tasks": parity["tasks"],
        "max_iterations": max_iterations,
        "cold": {"rps": cold["rps"], **cold["latency"]},
        "warm": {"rps": warm["rps"], **warm["latency"]},
        "warm_over_cold": ratio,
        "warm_solved": warm_solved,
        "batch_occupancy": cold_stats["batch_occupancy"],
        "deadline_fallbacks": cold_stats["deadline_fallbacks"],
        "hit_rate_total": warm_stats["hit_rate"],
        "bit_parity": parity["parity"],
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / "BENCH_serve.json"
    path.write_text(json.dumps(record, indent=2))
    print(f"--- serve ({path})")
    print(json.dumps(record, indent=2))

    # hard gates: parity always; warm pass must be pure cache; throughput
    # ratio outside smoke (tiny smoke runs are timing noise)
    assert parity["parity"], f"serve bit-parity FAILED: {parity['mismatches']}"
    assert warm_solved == 0, f"warm pass ran {warm_solved} solves"
    if not smoke:
        assert ratio >= 10.0, (
            f"warm-cache throughput only {ratio:.1f}x cold (need >= 10x)"
        )
    return record
