"""Mesh-sharded fleet scaling benchmark: ``pack_sweep`` at 1/2/4/8 shards.

The PR-8 deliverable (`--only sweep_sharded`): the Table-1 x (ZU7EV, U50)
x seeds sweep fleet, solved by ``pack_sweep(..., n_shards=k)`` at k = 1,
2, 4 and 8 host-platform shards, reporting aggregate candidates/sec and
the scaling ratio against the one-fleet baseline.  Sharding is an
execution-shape knob only, so every shard count must return **bit-
identical** per-candidate costs and packings (hard-asserted here — the
``parity`` column/flag).

Two outputs:

* ``sweep_sharded`` CSV (`benchmarks/out/sweep_sharded.csv`) — one row per
  shard count.
* ``BENCH_sweep.json`` (`benchmarks/out/BENCH_sweep.json`) — the
  machine-readable scaling record: candidates/sec per shard count,
  scaling ratios, the cost-parity flag, and the host shape
  (``n_cpus``/``n_devices``) the numbers were measured under.

Honest-throughput note (docs/DESIGN.md section 14): thread-level shard
concurrency can only beat the one-fleet baseline when the host has cores
(or devices) to run shards on.  On a 1-vCPU container the shards
time-slice one core, so candidates/sec stays roughly flat; the >= 3x
aggregate-throughput target at 8 shards is therefore asserted only when
``os.cpu_count() >= 8`` and otherwise reported with a warning line.  The
parity assertion is unconditional — results never depend on the host.
"""
from __future__ import annotations

import json
import os
import time

import repro.core as c

from .bench_dse import _fleet
from .common import OUT_DIR, emit

SHARD_COUNTS = (1, 2, 4, 8)
SPEEDUP_TARGET = 3.0  # >= 3x aggregate throughput at 8 shards (PR 8)


def _n_devices() -> int:
    try:
        import jax

        return len(jax.devices())
    except Exception:
        return 0


def run(quick: bool = False, n_chains: int = 8, iterations: int | None = None,
        smoke: bool = False):
    if smoke:
        n_chains = min(n_chains, 4)
    probs, seeds = _fleet(quick, smoke)
    iters = (
        iterations if iterations is not None
        else (60 if smoke else 800 if quick else 2000)
    )
    kw = dict(
        max_seconds=1e9, patience=10**9, max_iterations=iters,
        backend="python", n_chains=n_chains,
    )
    # warmup: one-time NFD/codec setup off the clocks
    c.pack_sweep(probs[:2], "sa-s", seeds=seeds[:2],
                 **{**kw, "max_iterations": 40})

    base_costs = None
    base_rate = None
    parity = True
    rows = []
    scaling: dict[str, dict] = {}
    for k in SHARD_COUNTS:
        t0 = time.perf_counter()
        sweep = c.pack_sweep(probs, "sa-s", seeds=seeds, n_shards=k, **kw)
        wall = time.perf_counter() - t0
        costs = [r.cost for r in sweep.results]
        if base_costs is None:
            base_costs = costs
            base_packings = [r.solution.state_dict() for r in sweep.results]
            base_rate = len(probs) / wall
        match = costs == base_costs and (
            [r.solution.state_dict() for r in sweep.results] == base_packings
        )
        parity = parity and match
        rate = len(probs) / wall
        rows.append([
            k, len(probs), sweep.n_groups, n_chains, iters, round(wall, 2),
            round(rate, 2), round(rate / base_rate, 2), match,
        ])
        scaling[str(k)] = {
            "wall_s": round(wall, 3),
            "candidates_per_sec": round(rate, 3),
            "speedup_vs_1_shard": round(rate / base_rate, 3),
        }
    header = [
        "n_shards", "candidates", "groups", "n_chains", "iters_per_candidate",
        "wall_s", "candidates_per_sec", "speedup_vs_1_shard", "costs_match",
    ]
    emit("sweep_sharded", header, rows)
    assert parity, "sharded sweeps must be bit-identical to n_shards=1"

    n_cpus = os.cpu_count() or 1
    top = scaling[str(SHARD_COUNTS[-1])]["speedup_vs_1_shard"]
    gated = n_cpus < SHARD_COUNTS[-1]
    record = {
        "bench": "sweep_sharded",
        "candidates": len(probs),
        "n_chains": n_chains,
        "iters_per_candidate": iters,
        "shard_counts": list(SHARD_COUNTS),
        "scaling": scaling,
        "cost_parity": parity,
        "speedup_target": SPEEDUP_TARGET,
        "speedup_at_max_shards": top,
        "speedup_target_met": top >= SPEEDUP_TARGET,
        "n_cpus": n_cpus,
        "n_devices": _n_devices(),
        "cpu_bound": gated,
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / "BENCH_sweep.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    print(f"--- BENCH_sweep.json ({path})")
    print(json.dumps(record, indent=2))
    if gated and top < SPEEDUP_TARGET:
        print(
            f"[warn] {top:.2f}x at {SHARD_COUNTS[-1]} shards on a "
            f"{n_cpus}-cpu host: shards time-slice the same core(s); the "
            f">= {SPEEDUP_TARGET}x target needs >= {SHARD_COUNTS[-1]} "
            "cores/devices (parity still holds)"
        )
    else:
        assert top >= SPEEDUP_TARGET, (
            f"expected >= {SPEEDUP_TARGET}x aggregate throughput at "
            f"{SHARD_COUNTS[-1]} shards, measured {top:.2f}x on "
            f"{n_cpus} cpus"
        )
    return record
