"""Paper Table 3: GA/SA x {buffer-swap, NFD} — BRAM cost + convergence time.

Reports, per accelerator and algorithm: best BRAM count over seeds, mean
time-to-within-1%-of-best (the paper's convergence metric), and the paper's
published (time, BRAM) for reference.  Wall-clock ratios (NFD vs swap) are
the claim under reproduction: >100x speedups on deep ResNets.
"""
from __future__ import annotations

import numpy as np

import repro.core as c

from .common import BUDGETS, SEEDS, emit

ALGOS = ("ga-s", "sa-s", "ga-nfd", "sa-nfd")


def run(accelerators=None, budgets=None, seeds=SEEDS):
    accelerators = accelerators or list(c.ACCELERATORS)
    budgets = budgets or BUDGETS
    header = [
        "accelerator", "algorithm", "bram_best", "bram_mean",
        "t_converge_mean_s", "paper_bram", "paper_t_s", "baseline_bram",
    ]
    rows = []
    paper_cols = {"ga-s": (0, 2), "sa-s": (1, 3), "ga-nfd": (4, 6), "sa-nfd": (5, 7)}
    for name in accelerators:
        prob = c.get_problem(name)
        hp = c.hyperparams(name)
        base = prob.baseline_cost()
        t3 = c.PAPER_TABLE3.get(name)
        for algo in ALGOS:
            costs, times = [], []
            for seed in seeds:
                r = c.pack(prob, algo, seed=seed, max_seconds=budgets[name], **hp)
                r.solution.validate()
                costs.append(r.cost)
                times.append(r.time_to_within(0.01))
            pt, pb = ("", "")
            if t3:
                ti, bi = paper_cols[algo]
                pt, pb = t3[ti], t3[bi]
            rows.append(
                [name, algo, int(min(costs)), float(np.mean(costs)),
                 round(float(np.mean(times)), 2), pb, pt, base]
            )
    emit("table3_algorithm_comparison", header, rows)
    return rows
