"""Paper Table 4: mapping-efficiency increase under GA-NFD (inter & intra)."""
from __future__ import annotations

import repro.core as c

from .common import BUDGETS, emit


def run(accelerators=None, budgets=None, seed=0):
    accelerators = accelerators or list(c.ACCELERATORS)
    budgets = budgets or BUDGETS
    header = [
        "accelerator", "mode", "bram", "efficiency_pct", "delta_bram_x",
        "paper_bram", "paper_eff_pct", "lower_bound",
    ]
    rows = []
    for name in accelerators:
        prob = c.get_problem(name)
        hp = c.hyperparams(name)
        base_cost = prob.baseline_cost()
        base_eff = prob.total_bits / (base_cost * prob.bram.capacity_bits)
        p4 = c.PAPER_TABLE4[name]
        rows.append(
            [name, "baseline", base_cost, round(base_eff * 100, 1), 1.0,
             p4[0], p4[1], prob.lower_bound()]
        )
        for mode, intra, pb, pe in (
            ("intra", True, p4[2], p4[3]),
            ("inter", False, p4[4], p4[5]),
        ):
            r = c.pack(
                prob, "ga-nfd", seed=seed, max_seconds=budgets[name],
                intra_layer=intra, **hp,
            )
            r.solution.validate(intra_layer=intra)
            rows.append(
                [name, mode, r.cost, round(r.efficiency * 100, 1),
                 round(base_cost / r.cost, 2), pb, pe, prob.lower_bound()]
            )
    emit("table4_efficiency_increase", header, rows)
    return rows
