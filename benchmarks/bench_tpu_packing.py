"""Beyond-paper: the TPU tile-grid adaptation on every assigned arch.

Plans packed banks over the *full* (abstract) parameter trees — per-layer
deployment view — and reports tile-padding efficiency before/after, bank
count, and packer runtime.  This is the paper's Table 4 transplanted to the
TPU memory hierarchy.
"""
from __future__ import annotations

import time

import jax

import repro.configs as configs
from repro.launch.specs import param_specs
from repro.memory import plan_packing

from .common import emit


def run(archs=None, budget_s: float = 5.0):
    archs = archs or list(configs.ARCHS)
    header = [
        "arch", "itemsize", "tensors_packed", "banks", "eff_before_pct",
        "eff_after_pct", "saved_bytes", "packer_s",
    ]
    rows = []
    for arch in archs:
        cfg = configs.get_config(arch)
        params = param_specs(cfg)  # abstract — planner needs shapes only
        t0 = time.perf_counter()
        plans = plan_packing(params, max_seconds=budget_s, split_stacked=True)
        dt = time.perf_counter() - t0
        for isz, plan in plans.items():
            if plan.padded_bytes_before == 0:
                continue
            rows.append(
                [arch, isz, sum(len(b) for b in plan.banks), len(plan.banks),
                 round(plan.efficiency_before() * 100, 2),
                 round(plan.efficiency_after() * 100, 2),
                 plan.saved_bytes, round(dt, 2)]
            )
    emit("tpu_tile_packing", header, rows)
    return rows
