"""Shared benchmark utilities: budgets, CSV emission."""
from __future__ import annotations

import csv
import io
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent / "out"

# per-accelerator optimization budgets (seconds) — scaled for the 1-vCPU
# host; the paper's Xeon ran 7-minute budgets
BUDGETS = {
    "CNV-W1A1": 6, "CNV-W2A2": 6, "Tincy-YOLO": 10, "DoReFaNet": 12,
    "ReBNet": 20, "RN50-W1A2": 30, "RN101-W1A2": 40, "RN152-W1A2": 45,
}
SEEDS = (0, 1)


def emit(name: str, header: list[str], rows: list[list]) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(header)
    w.writerows(rows)
    print(f"--- {name} ({path})")
    print(buf.getvalue())
