"""Benchmark harness — one function per paper table/figure + the TPU
adaptation and roofline tables.  Prints name,value CSVs (see each module).

  python -m benchmarks.run                # everything (tens of minutes)
  python -m benchmarks.run --only table4  # one table
  python -m benchmarks.run --quick        # reduced budgets (CI-scale)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="engine|hetero|sa|dse|table3|table4|fig45|tpu|"
                         "seqpack|kernels|roofline")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    from . import (
        bench_dse,
        bench_engine,
        bench_fig45,
        bench_kernels,
        bench_roofline,
        bench_seqpack,
        bench_table3,
        bench_table4,
        bench_tpu_packing,
    )
    from .common import BUDGETS

    budgets = {k: max(3, v // 4) for k, v in BUDGETS.items()} if args.quick else None
    small = ["CNV-W1A1", "CNV-W2A2", "Tincy-YOLO", "RN50-W1A2"] if args.quick else None

    jobs = {
        "engine": lambda: (
            bench_engine.run(quick=args.quick),
            bench_engine.run_hetero(quick=args.quick),
        ),
        "hetero": lambda: bench_engine.run_hetero(quick=args.quick),
        "sa": lambda: bench_engine.run_sa(quick=args.quick),
        "dse": lambda: bench_dse.run(quick=args.quick),
        "table3": lambda: bench_table3.run(accelerators=small, budgets=budgets),
        "table4": lambda: bench_table4.run(accelerators=small, budgets=budgets),
        "fig45": lambda: bench_fig45.run(budget_s=8 if args.quick else 25),
        "tpu": lambda: bench_tpu_packing.run(budget_s=2 if args.quick else 5),
        "seqpack": lambda: bench_seqpack.run(n_docs=500 if args.quick else 2000),
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    selected = [args.only] if args.only else list(jobs)
    for name in selected:
        t0 = time.perf_counter()
        jobs[name]()
        print(f"[bench {name} done in {time.perf_counter() - t0:.1f}s]\n")


if __name__ == "__main__":
    main()
