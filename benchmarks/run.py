"""Benchmark harness — one function per paper table/figure + the TPU
adaptation and roofline tables.  Prints name,value CSVs (see each module).

  python -m benchmarks.run                   # everything (tens of minutes)
  python -m benchmarks.run --only table4     # one table
  python -m benchmarks.run --only portfolio  # fleet vs thread portfolio
  python -m benchmarks.run --quick           # reduced budgets (CI-scale)
  python -m benchmarks.run --smoke           # execute every bench module in
                                             # seconds (rot check, no numbers)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="engine|hetero|sa|portfolio|racing|dse|sweep_sharded|"
                         "serve|table3|table4|fig45|tpu|seqpack|kernels|"
                         "roofline")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problems, 1-2 iterations, no meaningful "
                         "numbers — exercises every bench entry point so "
                         "they cannot rot unnoticed")
    args = ap.parse_args(argv)

    from . import (
        bench_dse,
        bench_engine,
        bench_fig45,
        bench_kernels,
        bench_racing,
        bench_roofline,
        bench_seqpack,
        bench_serve,
        bench_sweep_sharded,
        bench_table3,
        bench_table4,
        bench_tpu_packing,
    )
    from .common import BUDGETS, SEEDS

    quick, smoke = args.quick, args.smoke
    # per-mode knobs for the modules without their own smoke/quick switches
    # (bench_engine.run* and bench_dse.run take quick=/smoke= directly)
    if smoke:
        budgets = {k: 1 for k in BUDGETS}
        small = ["CNV-W1A1"]
        t3_seeds = (0,)
        fig_kw = dict(budget_s=0.5, seeds=(0,))
        tpu_kw = dict(archs=["hymba-1.5b"], budget_s=0.3)
        n_docs = 80
    else:
        budgets = {k: max(3, v // 4) for k, v in BUDGETS.items()} if quick else None
        small = (
            ["CNV-W1A1", "CNV-W2A2", "Tincy-YOLO", "RN50-W1A2"] if quick else None
        )
        t3_seeds = SEEDS
        fig_kw = dict(budget_s=8 if quick else 25)
        tpu_kw = dict(budget_s=2 if quick else 5)
        n_docs = 500 if quick else 2000

    jobs = {
        "engine": lambda: (
            bench_engine.run(quick=quick, smoke=smoke),
            bench_engine.run_hetero(quick=quick, smoke=smoke),
        ),
        "hetero": lambda: bench_engine.run_hetero(quick=quick, smoke=smoke),
        "sa": lambda: bench_engine.run_sa(quick=quick, smoke=smoke),
        "portfolio": lambda: bench_engine.run_portfolio(quick=quick, smoke=smoke),
        "racing": lambda: bench_racing.run(quick=quick, smoke=smoke),
        "dse": lambda: bench_dse.run(quick=quick, smoke=smoke),
        "sweep_sharded": lambda: bench_sweep_sharded.run(
            quick=quick, smoke=smoke
        ),
        "serve": lambda: bench_serve.run(quick=quick, smoke=smoke),
        "table3": lambda: bench_table3.run(
            accelerators=small, budgets=budgets, seeds=t3_seeds
        ),
        "table4": lambda: bench_table4.run(accelerators=small, budgets=budgets),
        "fig45": lambda: bench_fig45.run(**fig_kw),
        "tpu": lambda: bench_tpu_packing.run(**tpu_kw),
        "seqpack": lambda: bench_seqpack.run(n_docs=n_docs),
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    selected = [args.only] if args.only else list(jobs)
    for name in selected:
        t0 = time.perf_counter()
        jobs[name]()
        print(f"[bench {name} done in {time.perf_counter() - t0:.1f}s]\n")


if __name__ == "__main__":
    main()
