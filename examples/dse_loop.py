"""The paper's motivating use-case: memory packing inside a DSE inner loop.

A design-space exploration sweeps per-layer parallelism (N_PE, N_SIMD)
configurations; each candidate needs an OCM estimate *fast*.  The packer
runs in well under a second per candidate (paper section 2.3), so the DSE
can afford packed (not just baseline) BRAM counts when scoring.

    PYTHONPATH=src python examples/dse_loop.py
"""
import time

import repro.core as core
from repro.core.problem import PackingProblem, buffers_from_shape_rows


def fold_candidates():
    """Sweep folding factors of the CNV-W1A1 style accelerator: more PEs =
    more throughput = wider, shallower memories (lower baseline eff)."""
    base = core.TABLE1_ROWS["CNV-W1A1"]
    for fold in (1, 2, 4):
        rows = []
        for n_pe, (n_simd, depth, w) in base:
            rows.append((n_pe * fold, (n_simd, max(8, depth // fold), w)))
        yield fold, rows


def main():
    print(f"{'fold':>4} {'buffers':>8} {'baseline':>9} {'packed':>7} "
          f"{'eff%':>6} {'t_pack(s)':>9}")
    for fold, rows in fold_candidates():
        prob = PackingProblem(buffers_from_shape_rows(rows), name=f"fold{fold}")
        t0 = time.perf_counter()
        r = core.pack(prob, "sa-nfd", seed=0, max_seconds=3)
        dt = time.perf_counter() - t0
        print(f"{fold:>4} {prob.n:>8} {prob.baseline_cost():>9} {r.cost:>7} "
              f"{r.efficiency * 100:>6.1f} {dt:>9.2f}")
    print("the packer is fast enough to sit inside the DSE scoring loop")


if __name__ == "__main__":
    main()
