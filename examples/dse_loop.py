"""The paper's motivating use-case: memory packing inside a DSE inner loop.

A design-space exploration sweeps per-layer parallelism (folding) and
target-device candidates; each needs a packed OCM estimate fast (paper
section 2.3).  Instead of packing candidates one at a time, the whole
fold x device grid goes through ONE ``pack_sweep`` call: candidates sharing
a cost model are batched into a single vectorized annealer run (every
candidate still gets its exact standalone-seeded trajectory), duplicates
are served from the fingerprint cache, and the result is a ready-made
efficiency/Pareto table for the DSE scorer.

    PYTHONPATH=src python examples/dse_loop.py
"""
import repro.core as core
from repro.core.problem import PackingProblem, buffers_from_shape_rows


def fold_candidates():
    """Fold the CNV-W1A1 accelerator: more PEs = more throughput = wider,
    shallower memories (lower baseline mapping efficiency)."""
    base = core.TABLE1_ROWS["CNV-W1A1"]
    for fold in (1, 2, 4):
        rows = []
        for n_pe, (n_simd, depth, w) in base:
            rows.append((n_pe * fold, (n_simd, max(8, depth // fold), w)))
        yield fold, rows


def main():
    # the DSE grid: folding factor x target device (None = unbounded BRAM18)
    devices = (None, "ZU7EV", "U50")
    problems = []
    for fold, rows in fold_candidates():
        bufs = buffers_from_shape_rows(rows)
        for dev in devices:
            problems.append(
                PackingProblem(
                    bufs,
                    name=f"fold{fold}" + (f"@{dev}" if dev else ""),
                    ocm=core.get_ocm(dev) if dev else None,
                )
            )
    cache: dict = {}
    sweep = core.pack_sweep(
        problems, "sa-s", seed=0, n_chains=8,
        max_seconds=1e9, max_iterations=1500, patience=10**9, cache=cache,
    )
    print(sweep.table())
    # the DSE outer loop revisits candidates constantly — cached re-sweeps
    # are effectively free
    again = core.pack_sweep(
        problems, "sa-s", seed=0, n_chains=8,
        max_seconds=1e9, max_iterations=1500, patience=10**9, cache=cache,
    )
    print(f"re-sweep: {again.summary()}")
    print("one pack_sweep call scores the whole fold x device grid — fast "
          "enough to sit inside the DSE scoring loop")


if __name__ == "__main__":
    main()
