"""Quickstart: pack ResNet-50's parameter memories into FPGA BRAM.

Reproduces the paper's headline result (Table 4, RN50-W1A2): GA-NFD packs
896 parameter memories from ~64% baseline mapping efficiency to ~85%+,
around a 1.35x BRAM reduction, in seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import repro.core as core


def main():
    prob = core.get_problem("RN50-W1A2")
    print(f"ResNet-50 accelerator: {prob.n} parameter memories, "
          f"{prob.total_bits / 8 / 1024:.0f} KiB of weights")
    baseline = prob.singleton_solution()
    print(f"baseline (one memory per BRAM group): {baseline.cost()} BRAM, "
          f"{baseline.efficiency() * 100:.1f}% efficient")

    hp = core.hyperparams("RN50-W1A2")
    result = core.pack(prob, "ga-nfd", seed=0, max_seconds=20, **hp)
    result.solution.validate()
    print(result.summary())
    print(f"largest bin holds {result.solution.max_items_per_bin()} memories "
          f"(cardinality limit {prob.max_items} = BRAM port constraint)")
    print(f"paper's result for reference: 1374 BRAM @ 86.9% (inter-layer)")


if __name__ == "__main__":
    main()
