"""Batched serving with the paper's memory packing as a first-class feature.

Plans GA-NFD banks over the (per-layer) weight tensors, materializes the
PackedParameterStore, and serves from the packed views — outputs are
bit-identical to the unpacked model; the store reports the tile-padding
bytes recovered.
"""
import sys

from repro.launch.decode_demo import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "granite-moe-1b-a400m", "--batch", "2",
        "--prompt-len", "16", "--gen-len", "8", "--packed",
    ]
    main(argv)
