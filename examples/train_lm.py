"""End-to-end training driver example: a small qwen3-family LM with the
full production stack — NFD-packed data pipeline, AdamW, checkpointing,
NaN rollback, resume.

Defaults are CPU-feasible (~1-2 min). For the ~100M-parameter run used on
real hardware:
    python examples/train_lm.py --d-model 768 --layers 12 --steps 300 \
        --batch 8 --seq 1024
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "qwen3-0.6b", "--d-model", "128", "--layers", "4",
        "--vocab", "2048", "--steps", "30", "--batch", "4", "--seq", "256",
        "--ckpt-dir", "/tmp/repro_train_example",
    ]
    main(argv)
