"""repro: evolutionary bin packing for memory-efficient dataflow inference.

Layers: `repro.core` (the paper), `repro.memory` (TPU adaptation),
`repro.models`/`repro.sharding`/`repro.runtime` (the multi-pod framework),
`repro.launch` (mesh / dryrun / train / serve entry points).
"""
__version__ = "1.0.0"
