from .manager import (  # noqa: F401
    CheckpointManager,
    read_atomic_dir,
    write_atomic_dir,
)
