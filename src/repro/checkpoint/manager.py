"""Fault-tolerant checkpointing: atomic, hashed, mesh-agnostic, async.

Layout per step:  <dir>/step_000123/
    arrays.npz     — every leaf, keyed by its flattened tree path
    manifest.json  — treedef repr, shapes/dtypes, sha256 of arrays.npz,
                     data-iterator state, wall time

Guarantees:
* atomic: written to step_x.tmp then os.rename'd — a crash mid-save never
  corrupts the latest checkpoint;
* integrity: sha256 verified on restore; ``restore()`` (and
  ``restore_latest_valid()``) fall back to the newest step that passes the
  sha256/shape checks, logging what was skipped — a torn or corrupted
  latest step degrades gracefully instead of bricking the run;
* mesh-agnostic restore: leaves are saved as full (unsharded) host arrays
  and re-placed with the *target* mesh's NamedShardings at load, so a run
  can restart on a different topology (elastic scaling);
* async: save() can run on a background thread (wait() joins before the
  next save and re-raises anything the previous write died on); an atexit
  hook drains the in-flight write so interpreter shutdown can't tear it;
* keep_n garbage collection of old steps.
"""
from __future__ import annotations

import atexit
import hashlib
import json
import logging
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

logger = logging.getLogger(__name__)

_BF16_SUFFIX = "::bf16"


def write_atomic_dir(
    final: str | Path,
    flat: dict[str, np.ndarray],
    manifest: dict,
    *,
    tmp: str | Path | None = None,
    replace: bool = True,
) -> bool:
    """Publish ``{arrays.npz, manifest.json}`` atomically under ``final``.

    The shared integrity convention of every durable artifact in this repo
    (checkpoint steps, ``repro.serve`` result-store entries): arrays go to
    ``arrays.npz``, the manifest is stamped with its sha256, both land in a
    scratch dir that is ``os.rename``d into place — a crash mid-write can
    leave a stray ``*.tmp*`` dir but never a half-written ``final``.

    ``replace=False`` is the concurrent-writer contract: when ``final``
    already exists (another writer won the publish race) the scratch dir is
    discarded and ``False`` is returned — an existing entry is never
    touched, let alone half-overwritten.  With ``replace=True`` (the
    checkpoint-step behavior) an existing ``final`` is swapped out.
    ``tmp`` overrides the scratch path; the default carries pid + random
    bytes so concurrent writers cannot collide on it either.
    """
    final = Path(final)
    if tmp is None:
        tmp = final.with_name(
            f"{final.name}.tmp-{os.getpid()}-{os.urandom(4).hex()}"
        )
    tmp = Path(tmp)
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    np.savez(tmp / "arrays.npz", **flat)
    digest = hashlib.sha256((tmp / "arrays.npz").read_bytes()).hexdigest()
    (tmp / "manifest.json").write_text(
        json.dumps({**manifest, "sha256": digest}, indent=2)
    )
    if final.exists():
        if not replace:
            shutil.rmtree(tmp, ignore_errors=True)
            return False
        shutil.rmtree(final)
    try:
        os.rename(tmp, final)
    except OSError:
        if not replace and final.exists():
            # lost the publish race between the exists() check and the
            # rename: the other writer's entry stands, ours is discarded
            shutil.rmtree(tmp, ignore_errors=True)
            return False
        raise
    return True


def read_atomic_dir(path: str | Path) -> tuple[dict[str, np.ndarray], dict]:
    """Integrity-checked read of a :func:`write_atomic_dir` layout.

    Returns ``(flat, manifest)`` with bf16 views restored.  Raises
    ``IOError`` on a sha256 mismatch (and lets json/npz parse errors of a
    torn or scribbled entry propagate) — callers wanting graceful
    degradation catch and skip, as ``CheckpointManager.restore_latest_valid``
    and ``repro.serve.ResultStore.get`` do.
    """
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    blob = (path / "arrays.npz").read_bytes()
    if hashlib.sha256(blob).hexdigest() != manifest.get("sha256"):
        raise IOError(f"checkpoint {path} failed integrity check")
    flat: dict[str, np.ndarray] = {}
    with np.load(path / "arrays.npz") as arrays:
        for key in arrays.files:
            if key.endswith(_BF16_SUFFIX):
                flat[key[: -len(_BF16_SUFFIX)]] = arrays[key].view(
                    jax.numpy.bfloat16
                )
            else:
                flat[key] = arrays[key]
    return flat, manifest


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            out[key + _BF16_SUFFIX] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # a daemon writer thread dies mid-_write on normal interpreter exit,
        # which is exactly the torn-file failure the atomic rename protocol
        # exists to prevent — drain it before teardown
        atexit.register(self._drain)

    # ---------------------------------------------------------------- save
    def save(self, step: int, state, extra: dict | None = None) -> None:
        """Snapshot `state` (any pytree) + JSON-serializable `extra`.

        With ``async_save`` the write happens on a background thread; a
        failure there is re-raised by the *next* ``save()``/``wait()`` call
        rather than swallowed (a sweep must not run for hours believing it
        is checkpointed).
        """
        host_flat = _flatten(state)  # device->host copy happens here, sync
        self.wait()  # join the previous write; re-raise if it failed
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_guarded,
                args=(step, host_flat, extra or {}),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, host_flat, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _drain(self) -> None:
        """atexit hook: finish the in-flight background write, never raise."""
        thread = self._thread
        if thread is not None:
            thread.join()
            self._thread = None
        if self._error is not None:
            logger.error(
                "checkpoint background write under %s failed at exit: %r",
                self.dir, self._error,
            )

    def _write_guarded(self, step: int, flat: dict, extra: dict) -> None:
        try:
            self._write(step, flat, extra)
        except BaseException as e:  # surfaced by the next save()/wait()
            self._error = e

    def _write(self, step: int, flat: dict, extra: dict) -> None:
        write_atomic_dir(
            self.dir / f"step_{step:08d}",
            flat,
            {
                "step": step,
                "keys": sorted(flat.keys()),
                "time": time.time(),
                "extra": extra,
            },
            tmp=self.dir / f"step_{step:08d}.tmp",
        )
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        """Steps with a complete on-disk snapshot.

        Half-written ``.tmp`` dirs, half-deleted dirs (missing
        ``manifest.json`` or ``arrays.npz`` — e.g. a crash mid-``_gc``),
        and stray non-step paths are all ignored.
        """
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp":
                continue
            if not (p / "manifest.json").is_file() or not (p / "arrays.npz").is_file():
                continue
            try:
                out.append(int(p.name.split("_", 1)[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load(self, step: int) -> tuple[dict[str, np.ndarray], dict]:
        """Integrity-checked raw read of one step.

        Returns ``(flat, manifest)`` where ``flat`` maps flattened tree-path
        keys to host arrays (bf16 views restored).  Raises ``IOError`` on a
        sha256 mismatch — callers wanting graceful degradation go through
        :meth:`restore_latest_valid`.
        """
        return read_atomic_dir(self.dir / f"step_{step:08d}")

    def restore(
        self, like, step: int | None = None, shardings=None
    ) -> tuple[int, object, dict]:
        """Restore into the structure of `like` (abstract or concrete tree).

        Returns (step, state, extra).  With `shardings` (a matching pytree
        of NamedSharding) every leaf is placed sharded on the target mesh —
        the elastic-restart path.  Without an explicit ``step`` this is
        :meth:`restore_latest_valid`: a corrupt latest step falls back to
        the newest step that passes the integrity/shape checks.
        """
        if step is None:
            return self.restore_latest_valid(like, shardings=shardings)
        flat, manifest = self.load(step)

        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        flat_sh = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, (kpath, leaf) in enumerate(flat_like[0]):
            key = "/".join(
                str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                for p in kpath
            )
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch restoring {key}: ckpt {arr.shape} vs {leaf.shape}"
                )
            if flat_sh is not None:
                arr = jax.device_put(arr, flat_sh[i])
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(flat_like[1], leaves)
        return step, state, manifest.get("extra", {})

    def restore_latest_valid(
        self, like=None, shardings=None
    ) -> tuple[int, object, dict]:
        """Restore the newest step passing the sha256/shape checks.

        Corrupt or torn steps (bad hash, unreadable manifest/npz, shape
        mismatch against ``like``) are skipped with a warning — the crash-
        recovery contract is "degrade to the newest intact checkpoint",
        never "refuse to resume".  With ``like=None`` the raw flat
        ``{tree-path: array}`` dict is returned instead of an unflattened
        tree (the engine-state resume path, which knows its own layout).
        Raises ``FileNotFoundError`` when the directory has no steps at
        all, ``IOError`` when every step is damaged.
        """
        steps = self.all_steps()
        last_err: Exception | None = None
        for step in reversed(steps):
            try:
                if like is None:
                    flat, manifest = self.load(step)
                    return step, flat, manifest.get("extra", {})
                return self.restore(like, step=step, shardings=shardings)
            except Exception as e:
                last_err = e
                logger.warning(
                    "skipping corrupt checkpoint step %d under %s: %s",
                    step, self.dir, e,
                )
        if last_err is not None:
            raise IOError(
                f"no valid checkpoint under {self.dir} "
                f"({len(steps)} step(s) damaged; newest error: {last_err})"
            )
        raise FileNotFoundError(f"no checkpoints under {self.dir}")
