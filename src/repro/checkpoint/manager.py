"""Fault-tolerant checkpointing: atomic, hashed, mesh-agnostic, async.

Layout per step:  <dir>/step_000123/
    arrays.npz     — every leaf, keyed by its flattened tree path
    manifest.json  — treedef repr, shapes/dtypes, sha256 of arrays.npz,
                     data-iterator state, wall time

Guarantees:
* atomic: written to step_x.tmp then os.rename'd — a crash mid-save never
  corrupts the latest checkpoint;
* integrity: sha256 verified on restore;
* mesh-agnostic restore: leaves are saved as full (unsharded) host arrays
  and re-placed with the *target* mesh's NamedShardings at load, so a run
  can restart on a different topology (elastic scaling);
* async: save() can run on a background thread (wait() joins before the
  next save);
* keep_n garbage collection of old steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            out[key + "::bf16"] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state, extra: dict | None = None) -> None:
        """Snapshot `state` (any pytree) + JSON-serializable `extra`."""
        host_flat = _flatten(state)  # device->host copy happens here, sync
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_flat, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_flat, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        digest = hashlib.sha256((tmp / "arrays.npz").read_bytes()).hexdigest()
        manifest = {
            "step": step,
            "sha256": digest,
            "keys": sorted(flat.keys()),
            "time": time.time(),
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, like, step: int | None = None, shardings=None
    ) -> tuple[int, object, dict]:
        """Restore into the structure of `like` (abstract or concrete tree).

        Returns (step, state, extra).  With `shardings` (a matching pytree
        of NamedSharding) every leaf is placed sharded on the target mesh —
        the elastic-restart path.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        blob = (path / "arrays.npz").read_bytes()
        if hashlib.sha256(blob).hexdigest() != manifest["sha256"]:
            raise IOError(f"checkpoint {path} failed integrity check")
        arrays = np.load(path / "arrays.npz")

        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        flat_sh = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, (kpath, leaf) in enumerate(flat_like[0]):
            key = "/".join(
                str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                for p in kpath
            )
            if key + "::bf16" in arrays:
                arr = arrays[key + "::bf16"].view(jax.numpy.bfloat16)
            else:
                arr = arrays[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch restoring {key}: ckpt {arr.shape} vs {leaf.shape}"
                )
            if flat_sh is not None:
                arr = jax.device_put(arr, flat_sh[i])
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(flat_like[1], leaves)
        return step, state, manifest.get("extra", {})
