"""Assigned architecture configs (exact published hyperparameters) and
reduced smoke variants for CPU tests.

Every config cites its source; see per-module docstrings.  ``get_config(id)``
returns the full config, ``get_smoke_config(id)`` a structurally identical
but tiny variant (same block type, same features, small dims).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

from . import (
    granite_moe_1b,
    hymba_1_5b,
    mamba2_1_3b,
    phi3_vision_4_2b,
    phi35_moe_42b,
    qwen2_0_5b,
    qwen3_0_6b,
    qwen3_14b,
    starcoder2_7b,
    whisper_medium,
)

_MODULES = {
    "hymba-1.5b": hymba_1_5b,
    "qwen3-0.6b": qwen3_0_6b,
    "qwen2-0.5b": qwen2_0_5b,
    "qwen3-14b": qwen3_14b,
    "starcoder2-7b": starcoder2_7b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "whisper-medium": whisper_medium,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "mamba2-1.3b": mamba2_1_3b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {ARCHS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def shape_cells(arch: str) -> list[str]:
    """The live dry-run shape cells for this arch (documented skips removed)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    # long_500k only for sub-quadratic archs (SSM state / sliding window)
    if cfg.block in ("mamba2", "hymba"):
        cells.append("long_500k")
    return cells


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)
