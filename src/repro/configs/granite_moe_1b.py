"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8, head_dim=64) d_ff=512 vocab=49155,
MoE 32 experts top-8, SwiGLU experts, RMSNorm, tied embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    vocab_size=49_155,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    n_experts=32,
    top_k=8,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    attn_seq_shard=True,  # 8 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, n_experts=8, top_k=2, vocab_size=256,
)
