"""Hymba-1.5B [arXiv:2411.13676] — hybrid parallel attention + SSM heads.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16, parallel attn+mamba per block fused by per-branch RMSNorm
averaging.  Sliding-window attention (1024) everywhere except 3 global
full-attention layers (first / middle / last), as in the paper.  Hymba's
learnable meta tokens are represented by the first tokens of the sequence
(stub; noted in docs/DESIGN.md section 9).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    vocab_size=32_001,
    block="hymba",
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    d_ff=5504,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,  # smaller chunk halves the per-head L^2 decay-mask bytes
    rope_theta=10_000.0,
    attn_seq_shard=True,  # 5 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, ssm_state=8, ssm_head_dim=16, ssm_chunk=16,
    sliding_window=8, global_layers=(0, 2),
)
