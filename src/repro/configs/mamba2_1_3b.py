"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD stack.

48L d_model=2048, d_inner=2*d_model=4096, ssm_state=128, head_dim=64
(64 SSM heads), conv width 4, vocab=50280, no MLP (d_ff=0), RMSNorm,
tied embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    vocab_size=50_280,
    block="mamba2",
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, vocab_size=256,
)
