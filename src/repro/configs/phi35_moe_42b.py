"""Phi-3.5-MoE (42B total, 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=6400 vocab=32064,
MoE 16 experts top-2 in every layer, SwiGLU experts, LayerNorm.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    vocab_size=32_064,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    n_experts=16,
    top_k=2,
    mlp_gated=True,
    mlp_act="silu",
    norm="layernorm",
    rope_theta=10_000.0,
    attn_seq_shard=True,  # 8 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, n_experts=4, top_k=2, vocab_size=256,
)
