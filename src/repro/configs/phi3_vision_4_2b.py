"""Phi-3-vision-128k (4.2B) [hf:microsoft/Phi-3-vision-128k-instruct].

Phi-3-mini text backbone: 32L d_model=3072 32H (MHA kv=32, head_dim=96)
d_ff=8192 vocab=32064, SwiGLU, RMSNorm.  The CLIP vision tower is a STUB:
``input_specs()`` provides precomputed patch embeddings projected to
d_model, prepended to the token sequence.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    n_layers=32,
    d_model=3072,
    vocab_size=32_064,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    frontend="vision_stub",
    num_patches=1024,  # stub image -> 1024 patch embeddings
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=256, num_patches=8,
)
