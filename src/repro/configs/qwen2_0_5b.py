"""Qwen2-0.5B [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2, head_dim=64) d_ff=4864 vocab=151936,
QKV bias, SwiGLU, RMSNorm, tied embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    n_layers=24,
    d_model=896,
    vocab_size=151_936,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    qkv_bias=True,
    d_ff=4864,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    attn_seq_shard=True,  # 2 kv heads can't shard the 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256,
)
