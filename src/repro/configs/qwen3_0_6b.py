"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B, family spec hf:Qwen/Qwen3-8B].

28L d_model=1024 16H (GQA kv=8, head_dim=128) d_ff=3072 vocab=151936,
qk-norm, SwiGLU, RMSNorm, tied embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    vocab_size=151_936,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    qk_norm=True,
    d_ff=3072,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    attn_seq_shard=True,  # 8 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256,
)
