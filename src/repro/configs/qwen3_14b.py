"""Qwen3-14B [hf:Qwen/Qwen3-14B, family spec hf:Qwen/Qwen3-8B].

40L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=17408 vocab=151936,
qk-norm, SwiGLU, RMSNorm.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    n_layers=40,
    d_model=5120,
    vocab_size=151_936,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    qk_norm=True,
    d_ff=17408,
    mlp_gated=True,
    mlp_act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attn_seq_shard=True,  # 8 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256,
)
