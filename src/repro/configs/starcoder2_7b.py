"""StarCoder2-7B [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4, head_dim=128) d_ff=18432 vocab=49152,
RoPE, LayerNorm, plain GELU MLP with bias.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32,
    d_model=4608,
    vocab_size=49_152,
    n_heads=36,
    n_kv_heads=4,
    d_head=128,
    qkv_bias=True,
    attn_out_bias=True,
    d_ff=18432,
    mlp_gated=False,
    mlp_act="gelu",
    mlp_bias=True,
    norm="layernorm",
    rope_theta=1_000_000.0,
    attn_seq_shard=True,  # 4 kv heads vs 16-way model axis
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256,
)
