"""Whisper-medium [arXiv:2212.04356] — encoder-decoder backbone.

24L (each side) d_model=1024 16H (MHA kv=16, head_dim=64) d_ff=4096
vocab=51865, GELU MLP, LayerNorm, learned decoder positions, sinusoidal
encoder positions.  The conv1d audio frontend is a STUB per the brief:
``input_specs()`` provides precomputed frame embeddings (B, T, d_model).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    n_layers=24,
    n_encoder_layers=24,
    encoder_decoder=True,
    d_model=1024,
    vocab_size=51_865,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    mlp_gated=False,
    mlp_act="gelu",
    mlp_bias=True,
    qkv_bias=True,
    attn_out_bias=True,
    norm="layernorm",
    max_target_len=448,
    frontend="audio_stub",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256, max_target_len=16,
)
