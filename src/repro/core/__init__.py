"""Evolutionary bin packing for memory-efficient dataflow inference (core).

The paper's primary contribution: cardinality-constrained, variable-bin-size
bin packing of parameter memories onto physical RAM grids, solved with the
Next-Fit Dynamic heuristic hybridized into genetic algorithms and simulated
annealing.  `repro.memory` adapts the same machinery to TPU tile grids.
"""
from .accelerators import (  # noqa: F401
    ACCELERATORS,
    OCM_DEVICES,
    PAPER_TABLE2,
    PAPER_TABLE3,
    PAPER_TABLE4,
    TABLE1_ROWS,
    get_buffers,
    get_ocm,
    get_problem,
    hyperparams,
)
from .api import ALGORITHMS, make_packer, pack, pack_sweep  # noqa: F401
from .dse import SweepResult, solve_batch, task_key  # noqa: F401
from .ga import GeneticPacker, buffer_swap, kind_reassign  # noqa: F401
from .nfd import nfd_from_scratch, nfd_pack_order, nfd_repack  # noqa: F401
from .portfolio import (  # noqa: F401
    DEFAULT_RACE_GRID,
    IslandSpec,
    TruncationWarning,
    pack_portfolio,
    pack_portfolio_threads,
)
from .problem import (  # noqa: F401
    BRAM18,
    BRAM18_CAPACITY_BITS,
    BRAM18_MODES,
    DEFAULT_INVENTORY_PENALTY,
    BRAM36,
    BRAMSpec,
    Buffer,
    LUTRAM64,
    OCMInventory,
    PackingProblem,
    PackingResult,
    ProblemBatch,
    RAM_KINDS,
    RAMKind,
    Solution,
    URAM288,
    batch_group_key,
    buffers_from_shape_rows,
    decode_problem_batch,
    encode_problem_batch,
    greedy_assign_kinds,
    register_ram_kind,
)
from .sa import SimulatedAnnealingPacker  # noqa: F401
