"""The paper's Table 1 accelerator memory-shape sets.

Rows are ``(N_PE, (N_SIMD, D, W))`` exactly as printed in the paper; each row
expands to ``N_PE`` buffers of width ``N_SIMD*W`` bits and depth ``D`` (see
``problem.buffers_from_shape_rows``).

RN101/RN152 shape sets are not listed in the paper ("approximately 2x and 3x
deeper than ResNet-50 ... share the overall structure"); we reconstruct them
by scaling the RN50 row multiplicities by the published total-bits ratios
(derived from Table 4's baseline BRAM counts x efficiencies), which
reproduces the published baseline efficiency to within a fraction of a
percent.  This is recorded as a deviation in docs/DESIGN.md section 8.

``OCM_DEVICES`` adds per-device on-chip-memory inventories (nominal
datasheet BRAM18/URAM288 counts) for the heterogeneous model of
docs/DESIGN.md section 3: ``get_problem(name, device="U50")`` is the
one-liner that packs an accelerator onto mixed BRAM+URAM.
"""
from __future__ import annotations

from .problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
    buffers_from_shape_rows,
)

# ---------------------------------------------------------------- Table 1
TABLE1_ROWS: dict[str, list[tuple[int, tuple[int, int, int]]]] = {
    "CNV-W1A1": [
        (16, (32, 144, 1)),
        (16, (32, 288, 1)),
        (4, (32, 2304, 1)),
        (4, (1, 8192, 1)),
        (1, (32, 18432, 1)),
        (1, (4, 32768, 1)),
        (1, (8, 32768, 1)),
    ],
    "CNV-W2A2": [
        (8, (16, 576, 2)),
        (8, (16, 1152, 2)),
        (4, (1, 8192, 2)),
        (4, (8, 9216, 2)),
        (3, (2, 65536, 2)),
        (1, (8, 73728, 2)),
    ],
    "Tincy-YOLO": [
        (16, (32, 144, 1)),
        (25, (8, 320, 1)),
        (16, (32, 144, 1)),
        (80, (32, 2304, 1)),
    ],
    "DoReFaNet": [
        (136, (45, 72, 1)),
        (64, (34, 108, 1)),
        (32, (64, 108, 1)),
        (68, (3, 144, 1)),
        (8, (8, 64000, 1)),
        (4, (64, 65536, 1)),
        (8, (64, 73728, 1)),
    ],
    "ReBNet": [
        (64, (54, 256, 1)),
        (64, (25, 384, 1)),
        (64, (36, 384, 1)),
        (64, (32, 576, 1)),
        (128, (64, 1152, 1)),
        (40, (50, 2048, 1)),
        (128, (64, 2048, 1)),
    ],
    "RN50-W1A2": [
        (368, (32, 256, 1)),
        (32, (64, 256, 1)),
        (192, (64, 288, 1)),
        (176, (32, 1024, 1)),
        (32, (64, 1024, 1)),
        (96, (64, 1152, 1)),
    ],
}

# RN101/RN152: scale RN50 row multiplicities.  ResNet-101/152 add identical
# bottleneck blocks in stage 3, i.e. more buffers of the *same shapes*; the
# published baseline bits give scale factors 1.86x and 2.52x over RN50.
_RN_SCALES = {"RN101-W1A2": 1.859, "RN152-W1A2": 2.515}
for _name, _scale in _RN_SCALES.items():
    TABLE1_ROWS[_name] = [
        (max(1, round(n_pe * _scale)), shape) for n_pe, shape in TABLE1_ROWS["RN50-W1A2"]
    ]

ACCELERATORS = tuple(TABLE1_ROWS)

# Published results for validation (paper Tables 3 and 4).
PAPER_TABLE4 = {
    # name: (baseline_bram, baseline_eff_pct, intra_bram, intra_eff_pct,
    #        inter_bram, inter_eff_pct)
    "CNV-W1A1": (120, 69.3, 100, 82.3, 96, 86.6),
    "CNV-W2A2": (208, 79.9, 192, 86.6, 188, 88.4),
    "Tincy-YOLO": (578, 63.6, 456, 80.7, 420, 87.6),
    "DoReFaNet": (4116, 78.8, 3797, 85.4, 3794, 85.5),
    "ReBNet": (2880, 64.1, 2363, 78.1, 2352, 78.4),
    "RN50-W1A2": (2064, 57.9, 1440, 82.9, 1374, 86.9),
    "RN101-W1A2": (4240, 52.4, 2748, 80.9, 2616, 84.9),
    "RN152-W1A2": (5904, 50.9, 3758, 80.0, 3584, 83.9),
}

PAPER_TABLE3 = {
    # name: (t_ga_s, t_sa_s, bram_ga_s, bram_sa_s,
    #        t_ga_nfd, t_sa_nfd, bram_ga_nfd, bram_sa_nfd)
    "CNV-W1A1": (0.1, 0.2, 96, 96, 0.1, 0.1, 96, 96),
    "CNV-W2A2": (0.1, 0.1, 188, 190, 0.1, 0.1, 190, 188),
    "Tincy-YOLO": (1.8, 1.7, 420, 428, 0.1, 0.2, 430, 420),
    "DoReFaNet": (1.0, 1.6, 3849, 3823, 0.2, 0.1, 3826, 3794),
    "ReBNet": (40.1, 57.5, 2301, 2313, 2.2, 28.9, 2483, 2352),
    "RN50-W1A2": (239, 290, 1404, 1472, 0.8, 1.7, 1368, 1374),
    "RN101-W1A2": (615, 935, 3055, 2775, 0.9, 3.3, 2616, 2616),
    "RN152-W1A2": (1024, 1354, 3864, 4422, 1.5, 49, 3586, 3584),
}

# GA/SA hyperparameters per accelerator (paper Table 2).
PAPER_TABLE2 = {
    # name: dict(n_pop, n_tour, p_adm_w, p_adm_h, p_mut, sa_t0, sa_rc)
    "CNV-W1A1": dict(n_pop=50, n_tour=5, p_adm_w=0.0, p_adm_h=0.1, p_mut=0.3, sa_t0=30, sa_rc=1.0),
    "CNV-W2A2": dict(n_pop=50, n_tour=5, p_adm_w=0.0, p_adm_h=0.1, p_mut=0.3, sa_t0=30, sa_rc=2.0),
    "Tincy-YOLO": dict(n_pop=75, n_tour=5, p_adm_w=0.0, p_adm_h=0.2, p_mut=0.4, sa_t0=30, sa_rc=1.0),
    "DoReFaNet": dict(n_pop=50, n_tour=5, p_adm_w=0.1, p_adm_h=0.3, p_mut=0.4, sa_t0=30, sa_rc=1.0),
    "ReBNet": dict(n_pop=75, n_tour=5, p_adm_w=1.0, p_adm_h=0.2, p_mut=0.4, sa_t0=30, sa_rc=1.0),
    "RN50-W1A2": dict(n_pop=75, n_tour=5, p_adm_w=0.0, p_adm_h=0.1, p_mut=0.4, sa_t0=40, sa_rc=0.004),
    "RN101-W1A2": dict(n_pop=75, n_tour=5, p_adm_w=0.0, p_adm_h=0.1, p_mut=0.4, sa_t0=40, sa_rc=0.004),
    "RN152-W1A2": dict(n_pop=75, n_tour=5, p_adm_w=0.0, p_adm_h=0.1, p_mut=0.4, sa_t0=40, sa_rc=0.004),
}


# Per-device OCM inventories (nominal datasheet primitive counts; BRAM36
# blocks are modeled as two independent BRAM18s, the finer packing grain).
OCM_DEVICES: dict[str, OCMInventory] = {
    # Zynq UltraScale+ ZU7EV (ZCU104): 312 BRAM36 + 96 URAM288
    "ZU7EV": OCMInventory((BRAM18, URAM288), (624, 96), name="ZU7EV"),
    # Alveo U50 (VU35P, HBM): 1344 BRAM36 + 640 URAM288 — the interesting
    # regime: deep ResNets overflow BRAM alone but fit with URAM offload
    "U50": OCMInventory((BRAM18, URAM288), (2688, 640), name="U50"),
    # Alveo U250 (VU13P): 2688 BRAM36 + 1280 URAM288
    "U250": OCMInventory((BRAM18, URAM288), (5376, 1280), name="U250"),
    # Alveo U280 (VU37P, HBM): 2016 BRAM36 + 960 URAM288
    "U280": OCMInventory((BRAM18, URAM288), (4032, 960), name="U280"),
}


def get_ocm(device: str) -> OCMInventory:
    if device not in OCM_DEVICES:
        raise KeyError(
            f"unknown device {device!r}; options: {tuple(OCM_DEVICES)}"
        )
    return OCM_DEVICES[device]


def get_buffers(name: str) -> list[Buffer]:
    if name not in TABLE1_ROWS:
        raise KeyError(f"unknown accelerator {name!r}; options: {ACCELERATORS}")
    return buffers_from_shape_rows(TABLE1_ROWS[name])


def get_problem(
    name: str, max_items: int = 4, device: str | None = None
) -> PackingProblem:
    """Build a Table-1 problem; ``device`` selects a heterogeneous OCM
    inventory from ``OCM_DEVICES`` (default: unbounded BRAM18, the paper)."""
    return PackingProblem(
        get_buffers(name),
        max_items=max_items,
        name=name if device is None else f"{name}@{device}",
        ocm=get_ocm(device) if device is not None else None,
    )


def hyperparams(name: str) -> dict:
    return dict(PAPER_TABLE2.get(name, PAPER_TABLE2["RN50-W1A2"]))
