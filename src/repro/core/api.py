"""Single entry point for all memory packers."""
from __future__ import annotations

import time

import numpy as np

from . import baselines
from .dse import SweepResult, pack_sweep  # noqa: F401  (re-export)
from .ga import GeneticPacker
from .problem import (
    DEFAULT_INVENTORY_PENALTY,
    PackingProblem,
    PackingResult,
    Solution,
)
from .sa import SimulatedAnnealingPacker

ALGORITHMS = (
    "ga-nfd",
    "ga-s",
    "sa-nfd",
    "sa-s",
    "portfolio",
    "nfd",
    "ffd",
    "next-fit",
    "baseline",
)


def make_packer(
    algorithm: str,
    seed: int = 0,
    max_seconds: float = 30.0,
    intra_layer: bool = False,
    backend: str = "auto",
    **hyper,
):
    """Build a GA/SA packer from the paper's Table 2 hyperparameter names.

    Only the four evolutionary algorithms (``ga-nfd``/``ga-s``/``sa-nfd``/
    ``sa-s``) have packer objects; the one-shot heuristics are functions
    reached through :func:`pack`.  Keyword arguments:

    * ``seed`` — RNG seed; every engine/backend is deterministic per seed.
    * ``max_seconds`` — wall-clock budget; pair with the ``max_iterations``
      (SA) / ``max_generations`` (GA) hyperparameters for reproducible,
      budget-independent runs.
    * ``intra_layer`` — enforce the paper's intra-layer packing scenario
      (a bin never mixes buffers from different layers).
    * ``backend`` — evaluation engine: ``auto`` (Pallas on TPU, host
      evaluation on CPU), ``python`` (incremental scalar), ``ref`` (jit'd
      jnp), ``pallas`` (interpreter off-TPU), ``legacy`` (the seed's
      from-scratch scalar loop, kept for benchmarking).  All backends are
      bit-identical per seed.
    * ``hyper`` — Table-2 names (``n_pop``, ``n_tour``, ``p_mut``,
      ``p_adm_w``, ``p_adm_h``, ``sa_t0``, ``sa_rc``) plus the engine
      extensions (``n_chains``, ``exchange_every``, ``ladder_min/max``,
      ``patience``, ``swap_moves``, ``p_kind``, ``inventory_penalty``,
      ``max_iterations``, ``max_generations``).
    """
    algorithm = algorithm.lower()
    if algorithm in ("ga-nfd", "ga-s"):
        return GeneticPacker(
            mutation="nfd" if algorithm == "ga-nfd" else "swap",
            n_pop=hyper.get("n_pop", 50),
            n_tour=hyper.get("n_tour", 5),
            p_mut=hyper.get("p_mut", 0.4),
            p_adm_w=hyper.get("p_adm_w", 0.0),
            p_adm_h=hyper.get("p_adm_h", 0.1),
            nfd_threshold=hyper.get("nfd_threshold", 0.95),
            nfd_extra_frac=hyper.get("nfd_extra_frac", 0.01),
            nfd_max_bins=hyper.get("nfd_max_bins", 12),
            layer_weight=hyper.get("layer_weight", 0.01),
            intra_layer=intra_layer,
            max_seconds=max_seconds,
            max_generations=hyper.get("max_generations", 100_000),
            patience=hyper.get("patience", 200),
            seed=seed,
            backend=backend,
            p_kind=hyper.get("p_kind", 0.25),
            inventory_penalty=hyper.get(
                "inventory_penalty", DEFAULT_INVENTORY_PENALTY
            ),
        )
    if algorithm in ("sa-nfd", "sa-s"):
        return SimulatedAnnealingPacker(
            perturbation="nfd" if algorithm == "sa-nfd" else "swap",
            t0=hyper.get("sa_t0", 30.0),
            rc=hyper.get("sa_rc", 1.0),
            p_adm_w=hyper.get("p_adm_w", 0.0),
            p_adm_h=hyper.get("p_adm_h", 0.1),
            nfd_threshold=hyper.get("nfd_threshold", 0.95),
            nfd_extra_frac=hyper.get("nfd_extra_frac", 0.01),
            nfd_max_bins=hyper.get("nfd_max_bins", 8),
            swap_moves=hyper.get("swap_moves", 2),
            intra_layer=intra_layer,
            max_seconds=max_seconds,
            max_iterations=hyper.get("max_iterations", 2_000_000),
            patience=hyper.get("patience", 20_000),
            seed=seed,
            n_chains=hyper.get("n_chains", 1),
            backend=backend,
            exchange_every=hyper.get("exchange_every", 256),
            ladder_min=hyper.get("ladder_min", 0.25),
            ladder_max=hyper.get("ladder_max", 4.0),
            p_kind=hyper.get("p_kind", 0.15),
            inventory_penalty=hyper.get(
                "inventory_penalty", DEFAULT_INVENTORY_PENALTY
            ),
        )
    raise ValueError(f"no evolutionary packer named {algorithm!r}")


def pack(
    prob: PackingProblem,
    algorithm: str = "ga-nfd",
    seed: int = 0,
    max_seconds: float = 30.0,
    intra_layer: bool = False,
    backend: str = "auto",
    **hyper,
) -> PackingResult:
    """Pack `prob` with the named algorithm and return a PackingResult.

    Accepts the paper's Table 2 hyperparameter names: n_pop, n_tour, p_mut,
    p_adm_w, p_adm_h, sa_t0, sa_rc (see :func:`make_packer` for the full
    kwarg reference, including budgets).  ``intra_layer=True`` enforces
    the paper's intra-layer packing scenario.  ``backend`` selects the
    evaluation engine — "auto", "python", "ref", "pallas", or "legacy"
    (the seed's scalar loop, kept for benchmarking) — all bit-identical
    for a fixed seed.  For the GA the backends batch generation fitness;
    for "sa-s" they select the multi-chain annealer (pass ``n_chains=K``
    to run K temperature-laddered chains through the fused delta-cost
    kernel; "sa-nfd" always runs the scalar loop).

    On heterogeneous problems (``PackingProblem(ocm=...)`` — e.g.
    ``get_problem("RN152-W1A2", device="U50")``, with ``device`` naming an
    ``OCM_DEVICES`` inventory) every engine additionally explores per-bin
    RAM-kind reassignment (``p_kind``) and penalizes inventory overflow
    (``inventory_penalty`` per unit); single-kind problems are
    bit-identical to previous releases.

    To score many problems at once — the DSE use-case — see
    :func:`pack_sweep`, which batches a whole fleet through the vectorized
    engines with per-problem bit-parity to this function.
    """
    algorithm = algorithm.lower()
    if algorithm in ("ga-nfd", "ga-s", "sa-nfd", "sa-s"):
        packer = make_packer(
            algorithm,
            seed=seed,
            max_seconds=max_seconds,
            intra_layer=intra_layer,
            backend=backend,
            **hyper,
        )
        return packer.pack(prob)
    if algorithm == "portfolio":
        # the fleet-native island portfolio: deterministic per seed, with
        # migration at iteration/generation barriers (``migration_every``
        # counts iterations, not seconds; the legacy thread knob
        # ``max_workers`` is deprecated and ignored)
        from .portfolio import pack_portfolio

        return pack_portfolio(
            prob,
            seed=seed,
            max_seconds=max_seconds,
            intra_layer=intra_layer,
            backend=backend,
            **hyper,
        )

    # deterministic one-shot heuristics
    t0 = time.perf_counter()
    if algorithm == "nfd":
        from .nfd import nfd_from_scratch

        sol = nfd_from_scratch(
            prob,
            np.random.default_rng(seed),
            p_adm_w=hyper.get("p_adm_w", 0.0),
            p_adm_h=hyper.get("p_adm_h", 0.1),
            intra_layer=intra_layer,
        )
    elif algorithm == "ffd":
        sol = baselines.first_fit_decreasing(prob, intra_layer=intra_layer)
    elif algorithm == "next-fit":
        sol = baselines.next_fit(prob)
    elif algorithm == "baseline":
        sol = baselines.singleton(prob)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}; options: {ALGORITHMS}")
    wall = time.perf_counter() - t0
    cost = sol.cost()
    return PackingResult(
        solution=sol,
        cost=cost,
        efficiency=sol.efficiency(),
        wall_time_s=wall,
        algorithm=algorithm + ("-intra" if intra_layer else ""),
        trace=[(wall, cost)],
        iterations=1,
        params=dict(seed=seed, **hyper),
    )
