"""Classical bin-packing baselines the paper compares conceptually against.

The classical heuristics assume fixed bin capacity and unlimited cardinality;
under the paper's FPGA constraints (variable bin geometry on a BRAM grid +
cardinality limit) they perform poorly — reproducing that observation is the
point of keeping them here.  All return valid `Solution`s.
"""
from __future__ import annotations

import numpy as np

from .problem import PackingProblem, Solution, greedy_assign_kinds


def next_fit(prob: PackingProblem, order: np.ndarray | None = None) -> Solution:
    """Classical next-fit: close the open bin whenever adding a buffer would
    grow the bin's BRAM count (the closest analogue of a fixed capacity)."""
    if order is None:
        order = np.arange(prob.n)
    bins: list[list[int]] = []
    cur: list[int] = []
    cur_w = cur_h = 0
    for i in order:
        i = int(i)
        w, d = int(prob.widths[i]), int(prob.depths[i])
        if not cur:
            cur, cur_w, cur_h = [i], w, d
            continue
        new_w, new_h = max(cur_w, w), cur_h + d
        fits = (
            len(cur) < prob.max_items
            and prob.bin_cost(new_w, new_h) <= prob.bin_cost(cur_w, cur_h)
        )
        if fits:
            cur.append(i)
            cur_w, cur_h = new_w, new_h
        else:
            bins.append(cur)
            cur, cur_w, cur_h = [i], w, d
    if cur:
        bins.append(cur)
    return greedy_assign_kinds(Solution(prob, bins))


def first_fit_decreasing(prob: PackingProblem, intra_layer: bool = False) -> Solution:
    """Cardinality-constrained FFD (Kellerer/Pferschy-style adaptation).

    Buffers sorted by bit count descending; each is placed in the first bin
    where it (a) satisfies cardinality, (b) matches the bin width, and
    (c) does not increase the bin's allocated BRAM count.  Otherwise a new
    bin is opened.  O(n * bins)."""
    order = np.argsort(-(prob.widths * prob.depths), kind="stable")
    bins: list[list[int]] = []
    geom: list[tuple[int, int, int]] = []  # (width, height, cost)
    for i in order:
        i = int(i)
        w, d = int(prob.widths[i]), int(prob.depths[i])
        placed = False
        for bi, b in enumerate(bins):
            bw, bh, bc = geom[bi]
            if len(b) >= prob.max_items or bw != w:
                continue
            if intra_layer and int(prob.layers[b[0]]) != int(prob.layers[i]):
                continue
            nc = prob.bin_cost(bw, bh + d)
            if nc <= bc:
                b.append(i)
                geom[bi] = (bw, bh + d, nc)
                placed = True
                break
        if not placed:
            bins.append([i])
            geom.append((w, d, prob.bin_cost(w, d)))
    return greedy_assign_kinds(Solution(prob, bins))


def singleton(prob: PackingProblem) -> Solution:
    """The unpacked FINN baseline (one buffer per bin)."""
    return prob.singleton_solution()
