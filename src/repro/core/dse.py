"""Cross-problem batched DSE solver: pack a *fleet* of problems in one run.

The paper's motivating use-case (section 2.3) is memory packing inside a
design-space-exploration inner loop: every (network x folding x device x
precision) candidate needs a packed OCM estimate, and sweeps span hundreds
of candidates per accelerator build (the authors' sequel, arXiv:2011.07317).
Solving candidates one at a time leaves the batched kernels — which already
vectorize over chains and populations *within* one problem — idle across
the problem axis.  :func:`pack_sweep` closes that gap:

* Candidates are deduplicated by :meth:`PackingProblem.fingerprint` (and
  optionally served from a caller-owned ``cache`` dict), so repeated DSE
  candidates are free.
* The remaining fleet is grouped by cost-model signature
  (:func:`problem.batch_group_key`) and each group is padded to a common
  ``(NB, max_items)`` envelope (:func:`problem.encode_problem_batch`).
* ``sa-s`` groups run the multi-problem chain-block annealer
  (`SimulatedAnnealingPacker._anneal_block`): P problems x C chains advance
  in lock-step as one ``(P*C, ...)`` array program, with per-problem
  temperature ladders, best tracking, and early-exit freezing of converged
  problems.  Each problem consumes its own RNG stream, so its result is
  **bit-identical** to a standalone ``pack(prob, "sa-s", n_chains=C,
  seed=...)`` run — batching buys throughput, never different answers.
* ``ga-nfd``/``ga-s`` groups run a *lockstep* driver over the GA's phase
  helpers: mutations stay per-problem Python, but every generation's
  population fitness is evaluated in ONE leading-problem-axis
  ``binpack_fitness`` call over the stacked ``(P, n_pop, NB)`` matrices.
  Again bit-identical per problem to standalone runs.
* Everything else (``sa-nfd``, single-chain SA, ``legacy`` backends, the
  one-shot heuristics, ``portfolio``) falls back to a serial per-problem
  loop through :func:`api.pack` — same results, no batching.

Budget semantics: ``max_seconds`` is the wall-clock budget of one engine
*invocation* — a batched group shares one clock (its problems advance
together), the serial lane spends it per problem.  For reproducible,
parity-testable sweeps prefer iteration budgets (``max_iterations`` /
``max_generations`` with a huge ``max_seconds``), which freeze each problem
at exactly the same trajectory point as its standalone run.

Axes, padding, and masking contracts: docs/DESIGN.md section 10; the
paper-concept-to-code map lives in docs/ALGORITHMS.md.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

import numpy as np

from .ga import (
    lockstep_apply,
    lockstep_begin,
    lockstep_finish,
    stacked_population_costs,
)
from .problem import (
    PackingProblem,
    PackingResult,
    batch_group_key,
)

# algorithms whose batched lane exists (everything else runs serially)
_SA_BATCHED = ("sa-s",)
_GA_LOCKSTEP = ("ga-nfd", "ga-s")


def normalize_hyper(algorithm: str, hyper: dict) -> dict:
    """Apply the sweep-level hyperparameter defaults for ``algorithm``.

    ``pack_sweep`` gives ``sa-s`` fleets ``n_chains=8`` unless told
    otherwise; anything that derives task identities for sweep-solved work
    (the serve layer's request keys, ``ResultStore`` entries) must normalize
    the same way or identical requests would hash to different tasks.
    """
    out = dict(hyper)
    if algorithm.lower() in _SA_BATCHED:
        out.setdefault("n_chains", 8)
    return out


def task_key(
    prob: PackingProblem,
    algorithm: str,
    seed: int,
    intra_layer: bool = False,
    backend: str = "auto",
    max_seconds: float = 30.0,
    hyper: dict | None = None,
) -> tuple:
    """Stable identity of one solve: everything that can change its answer.

    Two requests with equal keys are interchangeable — same problem
    fingerprint, algorithm, seed, and settings — so they may share one
    result object (``pack_sweep`` dedups on this; ``repro.serve`` coalesces
    in-flight duplicates and keys its persistent store on it).  Callers
    passing ``hyper`` should run it through :func:`normalize_hyper` first
    if they want keys comparable with ``pack_sweep``'s.
    """
    hkey = tuple(sorted((k, repr(v)) for k, v in (hyper or {}).items()))
    return (
        prob.fingerprint(), algorithm.lower(), int(seed), bool(intra_layer),
        backend, float(max_seconds), hkey,
    )


# --------------------------------------------------------------- sweep result
@dataclasses.dataclass
class SweepResult:
    """Outcome of one :func:`pack_sweep` call.

    ``results[i]`` is the :class:`PackingResult` of ``problems[i]`` —
    positions with equal task fingerprints share one result object.
    ``fresh`` holds the positions that were actually solved this call (the
    rest came from the fingerprint dedup or the caller's ``cache``).
    """

    results: list[PackingResult]
    problems: list[PackingProblem]
    wall_time_s: float
    n_solved: int
    cache_hits: int
    n_groups: int
    algorithm: str
    fresh: tuple[int, ...] = ()
    #: sweep-level counters (PR 8): ``solved`` unique tasks solved this call,
    #: ``cache_hits`` unique tasks served from the cache / checkpoint store,
    #: ``dedup_hits`` positions collapsed by fingerprint dedup (so
    #: ``solved + cache_hits + dedup_hits == len(problems)``), plus the
    #: execution-shape knob ``n_shards``.
    params: dict = dataclasses.field(default_factory=dict)

    @property
    def size(self) -> int:
        return len(self.results)

    @property
    def candidates_per_sec(self) -> float:
        """Aggregate DSE throughput: candidates scored per wall second."""
        return self.size / max(self.wall_time_s, 1e-9)

    def costs(self) -> np.ndarray:
        return np.asarray([r.cost for r in self.results], dtype=np.int64)

    def pareto_indices(self) -> list[int]:
        """Non-dominated candidates over (cost down, Eq.-1 efficiency up).

        Across a sweep of *different* workloads this is the standard DSE
        screen: a candidate survives unless another candidate stores its
        bits at least as efficiently in no more RAM.  Callers with a real
        throughput model should build their own front from ``results``.
        """
        cost = self.costs()
        eff = np.asarray([r.efficiency for r in self.results])
        out = []
        for i in range(self.size):
            dominated = np.any(
                (cost <= cost[i]) & (eff >= eff[i])
                & ((cost < cost[i]) | (eff > eff[i]))
            )
            if not dominated:
                out.append(i)
        return out

    def table(self) -> str:
        """Efficiency/Pareto report, one row per candidate."""
        pareto = set(self.pareto_indices())
        fresh = set(self.fresh)
        lines = [
            f"{'#':>3} {'candidate':<24} {'bufs':>5} {'baseline':>9} "
            f"{'packed':>7} {'dBRAM':>6} {'eff%':>6} {'ovf':>5} {'src':>6} "
            f"{'pareto':>6}"
        ]
        for i, (prob, r) in enumerate(zip(self.problems, self.results)):
            ovf = r.solution.inventory_overflow()
            lines.append(
                f"{i:>3} {prob.name[:24]:<24} {prob.n:>5} "
                f"{prob.baseline_cost():>9} {r.cost:>7} "
                f"{r.baseline_cost / max(r.cost, 1):>6.2f} "
                f"{r.efficiency * 100:>6.1f} {ovf:>5} "
                f"{'solve' if i in fresh else 'cache':>6} "
                f"{'*' if i in pareto else '':>6}"
            )
        lines.append(self.summary())
        return "\n".join(lines)

    def summary(self) -> str:
        return (
            f"sweep[{self.algorithm}]: {self.size} candidates in "
            f"{self.wall_time_s:.2f}s ({self.candidates_per_sec:.2f}/s), "
            f"{self.n_solved} solved fresh in {self.n_groups} group(s), "
            f"{self.cache_hits} served from dedup/cache"
        )


def _task_keys(problems, algorithm, seeds, intra_layer, backend,
               max_seconds, hyper) -> list[tuple]:
    return [
        task_key(prob, algorithm, s, intra_layer, backend, max_seconds, hyper)
        for prob, s in zip(problems, seeds)
    ]


def _group_by_cost_model(indices, problems) -> list[list[int]]:
    """One group per cost-model signature — deliberately NOT sub-chunked by
    size: per-step work in the batched engines is dominated by
    ``(P*C, touched)``-shaped operations that barely see the padded
    envelope, so one big group amortizes the fixed per-step overhead best
    (measured: chunking a 16-candidate Table-1 fleet into 4 size-banded
    groups cut the speedup from ~4.5x to ~2.7x).  Grouping never changes
    results — each problem consumes its own RNG stream and padding never
    affects trajectories."""
    groups: dict = {}
    for i in indices:
        groups.setdefault(batch_group_key(problems[i]), []).append(i)
    return list(groups.values())


def shard_chunks(n: int, k: int) -> list[list[int]]:
    """Contiguous balanced split of ``range(n)`` into ``min(k, n)`` chunks.

    The first ``n % k`` chunks carry one extra row.  Contiguity is
    load-bearing: shard boundaries become plain row slices of the canonical
    merged checkpoint layout (``resume.merge_block_states``), so snapshots
    restore onto ANY shard count (docs/DESIGN.md section 14).
    """
    k = max(1, min(int(k), n))
    base, rem = divmod(n, k)
    out, lo = [], 0
    for i in range(k):
        size = base + (1 if i < rem else 0)
        out.append(list(range(lo, lo + size)))
        lo += size
    return out


def _shard_devices(mesh, n_chunks: int, backend: str):
    """Round-robin device pins for host-split shards (``n_shards > 1`` AND a
    mesh): shard ``i`` dispatches on ``devices[i % len]``.  With one chunk
    the mesh goes down the ``shard_map`` path instead, and the ``"python"``
    backend never touches jax devices."""
    if mesh is None or n_chunks <= 1 or backend not in ("ref", "pallas"):
        return None
    return list(mesh.devices.flat)


def _solve_sa_group_sharded(
    packer, probs, rngs, backend, n_shards, mesh, gkeys=None, ck=None
) -> list:
    """One cost-model group annealed as ``n_shards`` concurrent sub-fleets.

    Each shard is a contiguous problem slice started as its own
    `_block_start` block and advanced on a thread; per-problem trajectories
    are fleet-composition-independent (each live problem consumes only its
    own RNG stream and frozen problems never draw), so results are
    bit-identical to the one-fleet lane — pinned in ``tests/test_sharded.py``.
    Checkpoints are cut in the canonical MERGED layout
    (`resume.merge_block_states`), identical to the unsharded snapshot, so a
    crashed sharded sweep may resume at any other shard count.
    """
    chunks = shard_chunks(len(probs), n_shards)
    shard_mesh = mesh if len(chunks) == 1 else None
    devices = _shard_devices(mesh, len(chunks), backend)
    sts = [
        packer._block_start(
            [probs[j] for j in c], [rngs[j] for j in c],
            [[] for _ in c], backend, mesh=shard_mesh,
        )
        for c in chunks
    ]
    gd = None
    if ck is not None:
        from .resume import group_digest, merge_block_states

        gd = group_digest(gkeys)
        ck.restore_block_shards(gd, sts, packer.patience)

    def run(si, limit):
        st = sts[si]
        if st.done:
            return
        if devices is not None:
            import jax

            with jax.default_device(devices[si % len(devices)]):
                packer._block_run(st, limit)
        else:
            packer._block_run(st, limit)

    while not all(st.done for st in sts):
        if ck is None:
            limit = None  # each shard drains to its budgets in one call
        else:
            it = max(st.it for st in sts if not st.done)
            limit = (it // ck.every + 1) * ck.every
        live = [i for i, st in enumerate(sts) if not st.done]
        if len(live) == 1:
            run(live[0], limit)
        else:
            with ThreadPoolExecutor(max_workers=len(live)) as ex:
                for _ in ex.map(lambda si: run(si, limit), live):
                    pass
        if ck is not None and not all(st.done for st in sts):
            arrays, extra = merge_block_states(sts)
            ck.save_progress(group=gd, arrays=arrays, engine=extra)
    blocks = []
    for st in sts:
        blocks.extend(packer._block_finish(st))
    return blocks


def _solve_sa_groups(
    packer, groups, problems, seeds, backend, keys=None, ck=None,
    n_shards=1, mesh=None,
) -> dict[int, PackingResult]:
    out: dict[int, PackingResult] = {}
    for group in groups:
        probs = [problems[i] for i in group]
        rngs = [np.random.default_rng(seeds[i]) for i in group]
        packer._hetero = probs[0].n_kinds > 1
        if n_shards > 1 and len(group) > 1:
            gkeys = [keys[i] for i in group] if keys is not None else None
            blocks = _solve_sa_group_sharded(
                packer, probs, rngs, backend, n_shards, mesh,
                gkeys=gkeys, ck=ck,
            )
        elif ck is None:
            blocks = packer._anneal_block(
                probs, rngs, [[] for _ in group], backend, mesh=mesh
            )
        else:
            # checkpointed lane: same start/run/finish phases, but paused at
            # iteration barriers for durable snapshots.  Barrier segmentation
            # never changes trajectories (the PR-5 resumable-engine contract),
            # so results stay bit-identical to the uncheckpointed lane.
            from .resume import encode_block_state, group_digest

            gd = group_digest([keys[i] for i in group])
            st = packer._block_start(
                probs, rngs, [[] for _ in group], backend, mesh=mesh
            )
            ck.restore_block(gd, st)  # overwrite from snapshot if it matches
            while not st.done:
                packer._block_run(st, (st.it // ck.every + 1) * ck.every)
                if not st.done:
                    arrays, extra = encode_block_state(st)
                    ck.save_progress(group=gd, arrays=arrays, engine=extra)
            blocks = packer._block_finish(st)
        for i, blk in zip(group, blocks):
            packer.seed = seeds[i]  # per-problem seed lands in result params
            out[i] = packer._result(
                blk.best, blk.best_cost, blk.wall, blk.trace,
                blk.iterations, backend, uphill=blk.uphill,
            )
            if ck is not None:
                ck.mark_done(keys[i], out[i])
        if ck is not None:
            ck.save_progress()  # group complete: results only, no engine state
    return out


def _lockstep_drain(pairs, gen_limit=None, mesh=None) -> bool:
    """One lockstep generation through the GA segment API — identical to
    ``ga.lockstep_generation`` (which wraps the same phases), written out so
    the sweep lane exercises the begin/apply/finish contract the portfolio's
    fused barrier dispatch builds on."""
    advanced, batches = lockstep_begin(pairs, gen_limit)
    for batch in batches:
        lockstep_apply(
            batch,
            stacked_population_costs(
                [r for _, r, _ in batch], batch[0][1].backend, mesh=mesh
            ),
        )
    return lockstep_finish(advanced)


def _solve_ga_groups(
    packer, groups, problems, seeds, backend, keys=None, ck=None,
    n_shards=1, mesh=None,
) -> dict[int, PackingResult]:
    out: dict[int, PackingResult] = {}
    for group in groups:
        runs = [
            packer._start_run(
                problems[i], np.random.default_rng(seeds[i]), None, backend
            )
            for i in group
        ]
        chunks = shard_chunks(len(runs), n_shards)
        shard_mesh = mesh if len(chunks) == 1 else None
        devices = _shard_devices(mesh, len(chunks), backend)
        totals = stacked_population_costs(runs, backend, mesh=shard_mesh)
        for run, tot in zip(runs, totals):
            packer._eval_init(run, tot)
        # drive the GA segment API directly (ga.lockstep_begin / apply /
        # finish): per generation, one mutation phase across every live run,
        # one stacked fitness call per population-size batch, then
        # selection — the same phases the fleet-native portfolio fuses with
        # SA work at its barriers (docs/DESIGN.md section 13).  With
        # ``n_shards > 1`` the group's runs split into contiguous lockstep
        # sub-packs, each drained on its own thread: fitness values are
        # per-individual, so stack membership never changes any trajectory
        # (pinned in tests/test_sharded.py).
        pair_chunks = [[(packer, runs[j]) for j in c] for c in chunks]

        def drain_chunk(ci, glimit):
            pc = pair_chunks[ci]
            if devices is not None:
                import jax

                with jax.default_device(devices[ci % len(devices)]):
                    while _lockstep_drain(pc, glimit):
                        pass
            else:
                while _lockstep_drain(pc, glimit, mesh=shard_mesh):
                    pass

        def drain_all(glimit):
            live = [
                ci for ci, c in enumerate(chunks)
                if any(not runs[j].done for j in c)
            ]
            if len(live) <= 1:
                for ci in live:
                    drain_chunk(ci, glimit)
            else:
                with ThreadPoolExecutor(max_workers=len(live)) as ex:
                    for _ in ex.map(lambda ci: drain_chunk(ci, glimit), live):
                        pass

        if ck is None:
            drain_all(None)
        else:
            from .resume import encode_ga_group, group_digest

            gd = group_digest([keys[i] for i in group])
            ck.restore_ga_group(gd, runs)
            while True:
                live = [run.gen for run in runs if not run.done]
                if not live:
                    break
                glimit = (min(live) // ck.every + 1) * ck.every
                drain_all(glimit)
                if all(run.done for run in runs):
                    break
                arrays, extras = encode_ga_group(runs)
                ck.save_progress(group=gd, arrays=arrays, engine=extras)
        for i, run in zip(group, runs):
            packer.seed = seeds[i]  # per-problem seed lands in result params
            out[i] = packer._finish_run(run)
            if ck is not None:
                ck.mark_done(keys[i], out[i])
        if ck is not None:
            ck.save_progress()
    return out


def _solve_positions(
    todo, problems, seeds, algorithm, *, seed=0, max_seconds=30.0,
    intra_layer=False, backend="auto", keys=None, ck=None, n_shards=1,
    mesh=None, hyper=None,
) -> tuple[dict[int, PackingResult], int]:
    """Solve the given positions of ``problems`` through the right lane.

    The shared lane dispatcher behind :func:`pack_sweep` (which feeds it
    the deduplicated representatives) and :func:`solve_batch` (which feeds
    it everything).  Returns ``({position: result}, n_groups)``.
    """
    from .api import make_packer, pack as _pack  # late: api re-exports us

    hyper = hyper or {}
    solved: dict[int, PackingResult] = {}
    todo = sorted(todo)
    if not todo:
        return solved, 0
    if algorithm in _SA_BATCHED or algorithm in _GA_LOCKSTEP:
        packer = make_packer(
            algorithm, seed=seed, max_seconds=max_seconds,
            intra_layer=intra_layer, backend=backend, **hyper,
        )
        resolved = packer._resolve_backend()
    else:
        packer = resolved = None
    if (
        algorithm in _SA_BATCHED
        and resolved != "legacy"
        and packer.n_chains > 1
    ):
        groups = _group_by_cost_model(todo, problems)
        solved = _solve_sa_groups(
            packer, groups, problems, seeds, resolved, keys=keys, ck=ck,
            n_shards=n_shards, mesh=mesh,
        )
    elif algorithm in _GA_LOCKSTEP and resolved in ("ref", "pallas"):
        groups = _group_by_cost_model(todo, problems)
        solved = _solve_ga_groups(
            packer, groups, problems, seeds, resolved, keys=keys, ck=ck,
            n_shards=n_shards, mesh=mesh,
        )
    else:
        # serial fallback: scalar/legacy engines, heuristics, portfolio.
        # Checkpoint granularity here is whole candidates: each finished
        # solve is durable, an in-flight one restarts from scratch.
        groups = [[i] for i in todo]
        for i in todo:
            solved[i] = _pack(
                problems[i], algorithm, seed=seeds[i],
                max_seconds=max_seconds, intra_layer=intra_layer,
                backend=backend, **hyper,
            )
            if ck is not None:
                ck.mark_done(keys[i], solved[i])
                ck.save_progress()
    return solved, len(groups)


def solve_batch(
    problems: Sequence[PackingProblem],
    algorithm: str = "sa-s",
    seed: int = 0,
    seeds: Sequence[int] | None = None,
    max_seconds: float = 30.0,
    intra_layer: bool = False,
    backend: str = "auto",
    n_shards: int = 1,
    mesh=None,
    **hyper,
) -> list[PackingResult]:
    """Solve one micro-batch of problems as a single batched fleet.

    The reusable single-batch entry point behind the serving layer
    (``repro.serve.PackingService`` executes every flushed micro-batch
    through this on its worker lane): no dedup, no caching, no
    checkpointing — just the lane dispatch of :func:`pack_sweep` applied to
    *every* position, returning one :class:`PackingResult` per problem in
    order.  Callers should pre-group compatible problems with
    :func:`repro.core.problem.batch_group_key` when they want exactly one
    fleet per call; mixed batches still work (they split into one group per
    cost model).  Results carry the same bit-parity guarantee as
    ``pack_sweep``: each is identical to the standalone
    ``pack(problems[i], algorithm, seed=seeds[i], ...)`` run.
    """
    problems = list(problems)
    if not problems:
        raise ValueError("solve_batch needs at least one problem")
    algorithm = algorithm.lower()
    if seeds is None:
        seeds = [seed] * len(problems)
    else:
        seeds = [int(s) for s in seeds]
        if len(seeds) != len(problems):
            raise ValueError("seeds must align with problems")
    hyper = normalize_hyper(algorithm, hyper)
    solved, _ = _solve_positions(
        range(len(problems)), problems, seeds, algorithm, seed=seed,
        max_seconds=max_seconds, intra_layer=intra_layer, backend=backend,
        n_shards=int(n_shards), mesh=mesh, hyper=hyper,
    )
    return [solved[i] for i in range(len(problems))]


def pack_sweep(
    problems: Sequence[PackingProblem],
    algorithm: str = "sa-s",
    seed: int = 0,
    seeds: Sequence[int] | None = None,
    max_seconds: float = 30.0,
    intra_layer: bool = False,
    backend: str = "auto",
    cache: dict | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 256,
    resume: bool = False,
    on_checkpoint=None,
    n_shards: int = 1,
    mesh=None,
    **hyper,
) -> SweepResult:
    """Solve a fleet of packing problems in one vectorized run.

    Parameters mirror :func:`api.pack` (the paper's Table-2 hyperparameter
    names pass through ``hyper``), applied to every candidate:

    * ``problems`` — the DSE candidates; duplicates (by
      :meth:`PackingProblem.fingerprint` + seed + settings) are solved once.
    * ``seed`` / ``seeds`` — one base seed for all candidates (the default,
      which maximizes dedup), or an explicit per-candidate seed list.
    * ``intra_layer`` — forbid mixing layers within a bin, as in the
      paper's intra-layer packing scenario (applies fleet-wide).
    * ``backend`` — evaluation backend, as in :func:`api.pack`; the batched
      lanes need a non-``legacy`` backend and otherwise fall back to the
      serial loop.
    * ``cache`` — optional caller-owned dict carrying solutions across
      sweeps; hits skip solving entirely (the DSE outer loop revisits
      candidates constantly).
    * ``algorithm="sa-s"`` (the default) gets ``n_chains=8`` unless given;
      each candidate's result is bit-identical to the standalone
      ``pack(prob, algorithm, seed=..., n_chains=...)`` run, so batching
      changes throughput only — never answers (pinned in
      ``tests/test_dse.py``).

    Crash safety (docs/DESIGN.md section 12): with ``checkpoint_dir`` the
    sweep cuts a durable snapshot every ``checkpoint_every`` engine
    iterations/generations (plus one per completed group) — completed
    candidates and the in-flight batched group's full engine state.
    ``resume=True`` restarts from the newest *intact* snapshot (corrupt or
    torn steps are skipped) and, because every engine is deterministic from
    any barrier state, lands on results **bit-identical** to an
    uninterrupted same-seed run (pinned by ``tests/test_resume.py``).
    ``on_checkpoint(step)`` fires after each durable write (the
    fault-injection hook).  Resumed-from-checkpoint candidates count as
    cache hits, not fresh solves.

    Scaling past one device (PR 8, docs/DESIGN.md section 14):

    * ``n_shards`` — split each batched group into that many contiguous
      sub-fleets (SA) / lockstep sub-packs (GA), advanced concurrently on
      threads.  Per-problem trajectories are fleet-composition-independent,
      so any shard count is **bit-identical** to ``n_shards=1`` (pinned in
      ``tests/test_sharded.py``); checkpoints are cut in a canonical merged
      layout, so a crashed sharded sweep resumes at any other shard count.
    * ``mesh`` — a 1-D ``("prob",)`` device mesh
      (:func:`repro.launch.mesh.make_sweep_mesh`).  With ``n_shards=1`` the
      batched kernels row-shard each step over the mesh via ``shard_map``;
      with ``n_shards > 1`` the sub-fleets are instead pinned round-robin
      to the mesh's devices.  Jax backends ("ref"/"pallas") only; the
      ``"python"`` backend and the serial fallback lane ignore both knobs.
    """
    problems = list(problems)
    if not problems:
        raise ValueError("pack_sweep needs at least one problem")
    algorithm = algorithm.lower()
    if seeds is None:
        seeds = [seed] * len(problems)
    else:
        seeds = [int(s) for s in seeds]
        if len(seeds) != len(problems):
            raise ValueError("seeds must align with problems")
    hyper = normalize_hyper(algorithm, hyper)
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    t_start = time.perf_counter()

    keys = _task_keys(problems, algorithm, seeds, intra_layer, backend,
                      max_seconds, hyper)
    ck = None
    if checkpoint_dir is not None:
        from .resume import SweepCheckpointer, sweep_config_key

        ck = SweepCheckpointer(
            checkpoint_dir, sweep_config_key(keys), every=checkpoint_every,
            resume=resume, on_checkpoint=on_checkpoint,
        )
    results_by_key: dict[tuple, PackingResult] = {}
    if cache is not None:
        for k in set(keys):
            if k in cache:
                results_by_key[k] = cache[k]
    if ck is not None:
        # candidates completed before the crash are served, not re-solved
        for i, k in enumerate(keys):
            if k not in results_by_key:
                prev = ck.result_for(k, problems[i])
                if prev is not None:
                    results_by_key[k] = prev
    rep: dict[tuple, int] = {}  # first position of each unsolved unique task
    for i, k in enumerate(keys):
        if k not in results_by_key and k not in rep:
            rep[k] = i
    fresh = tuple(sorted(rep.values()))
    cache_hits = len(problems) - len(fresh)

    # --- lane dispatch for the unsolved representatives
    n_groups = 0
    if rep:
        solved, n_groups = _solve_positions(
            rep.values(), problems, seeds, algorithm, seed=seed,
            max_seconds=max_seconds, intra_layer=intra_layer,
            backend=backend, keys=keys, ck=ck, n_shards=n_shards, mesh=mesh,
            hyper=hyper,
        )
        for i, res in solved.items():
            results_by_key[keys[i]] = res
            if cache is not None:
                cache[keys[i]] = res

    return SweepResult(
        results=[results_by_key[k] for k in keys],
        problems=problems,
        wall_time_s=time.perf_counter() - t_start,
        n_solved=len(fresh),
        cache_hits=cache_hits,
        n_groups=n_groups,
        algorithm=algorithm,
        fresh=fresh,
        params=dict(
            solved=len(fresh),
            cache_hits=len(set(keys)) - len(fresh),
            dedup_hits=len(problems) - len(set(keys)),
            n_shards=n_shards,
        ),
    )
