"""Genetic-algorithm memory packer — Algorithm 2 of the paper.

Bin-per-gene chromosome (Falkenauer encoding): an individual IS a packing
solution; each gene is one bin (a group of buffer indices).  There is no
crossover — as in the paper, mutation (buffer swap for GA-S, NFD repack for
GA-NFD) drives exploration, and tournament selection drives exploitation.
Fitness is the multi-objective weighted sum of BRAM cost and mean distinct
layers per bin (placement locality).

Evaluation backends (`GeneticPacker(backend=...)`):

* ``"python"`` — incremental scalar path: mutations carry per-bin record
  caches (see `Solution`), so evaluating a mutated individual is O(touched
  bins).
* ``"ref"`` / ``"pallas"`` — batched path: the population's bin geometry
  lives in padded ``(P, NB)`` int32 matrices updated in place from each
  mutation's dirty bins, and the whole generation's costs are computed in one
  `kernels.binpack_fitness.ops.population_costs` call (pure jnp on CPU,
  Pallas kernel on TPU).
* ``"auto"`` — ``pallas`` when a TPU is attached, else ``ref``.
* ``"legacy"`` — the seed's from-scratch scalar evaluation (no caches), kept
  as the benchmark baseline; identical RNG stream and results.

All backends are bit-identical for a fixed seed: cost arithmetic is exact
integer math and the RNG consumption order never depends on the backend.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from .nfd import nfd_from_scratch, nfd_repack
from .problem import PackingProblem, PackingResult, Solution

BACKENDS = ("auto", "python", "ref", "pallas", "legacy")


def buffer_swap(
    sol: Solution, rng: np.random.Generator, n_moves: int = 1, intra_layer: bool = False
) -> Solution:
    """MPack-style perturbation: move random buffers between random bins.

    Reports every touched bin to the solution's record cache, so the child's
    ``cost()`` re-evaluates at most ``2 * n_moves`` bins.
    """
    out = sol.copy()
    prob = out.problem
    for _ in range(n_moves):
        if len(out.bins) < 2:
            break
        src = int(rng.integers(len(out.bins)))
        dst = int(rng.integers(len(out.bins)))
        if src == dst or not out.bins[src]:
            continue
        item = out.bins[src][int(rng.integers(len(out.bins[src])))]
        dst_bin = out.bins[dst]
        if intra_layer and dst_bin and int(prob.layers[dst_bin[0]]) != int(
            prob.layers[item]
        ):
            continue
        if len(dst_bin) >= prob.max_items:
            # swap instead of move to preserve cardinality feasibility
            j = int(rng.integers(len(dst_bin)))
            other = dst_bin[j]
            if intra_layer and int(prob.layers[other]) != int(
                prob.layers[out.bins[src][0]] if out.bins[src] else prob.layers[item]
            ):
                continue
            dst_bin[j] = item
            out.bins[src][out.bins[src].index(item)] = other
        else:
            out.bins[src].remove(item)
            dst_bin.append(item)
        out.touch(src, dst)
    out.drop_empty()
    return out


def fitness(sol: Solution, layer_weight: float, cost: int | float | None = None) -> float:
    """Weighted-sum fitness; pass a precomputed ``cost`` to avoid re-deriving it."""
    f = float(sol.cost() if cost is None else cost)
    if layer_weight > 0.0:
        f += layer_weight * sol.distinct_layers_per_bin()
    return f


class GeneticPacker:
    def __init__(
        self,
        mutation: str = "nfd",  # "nfd" (GA-NFD) or "swap" (GA-S)
        n_pop: int = 50,
        n_tour: int = 5,
        p_mut: float = 0.4,
        p_adm_w: float = 0.0,
        p_adm_h: float = 0.1,
        nfd_threshold: float = 0.95,
        nfd_extra_frac: float = 0.01,
        nfd_max_bins: int = 12,
        swap_moves: int = 4,
        layer_weight: float = 0.01,
        intra_layer: bool = False,
        max_seconds: float = 60.0,
        max_generations: int = 100_000,
        patience: int = 200,
        seed: int = 0,
        backend: str = "auto",
    ):
        if mutation not in ("nfd", "swap"):
            raise ValueError(f"unknown mutation {mutation!r}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
        self.__dict__.update(locals())
        del self.__dict__["self"]
        # warm state for portfolio restarts (set after each pack())
        self.last_population_: list[Solution] | None = None

    @property
    def name(self) -> str:
        return "GA-NFD" if self.mutation == "nfd" else "GA-S"

    def _resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        try:
            import jax

            return "pallas" if jax.default_backend() == "tpu" else "ref"
        except Exception:
            return "python"

    def _mutate(
        self, sol: Solution, rng: np.random.Generator, use_cache: bool = True
    ) -> Solution:
        if self.mutation == "nfd":
            return nfd_repack(
                sol,
                rng,
                threshold=self.nfd_threshold,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                extra_frac=self.nfd_extra_frac,
                max_bins=self.nfd_max_bins,
                use_cache=use_cache,
            )
        return buffer_swap(
            sol, rng, n_moves=self.swap_moves, intra_layer=self.intra_layer
        )

    # ---------------------------------------------------------------- eval
    @staticmethod
    def _batched_costs(W: np.ndarray, H: np.ndarray, backend: str) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels.binpack_fitness.ops import population_costs

        interpret = backend == "pallas" and _default_jax_backend() != "tpu"
        totals = population_costs(
            jnp.asarray(W), jnp.asarray(H), backend=backend, interpret=interpret
        )
        return np.asarray(totals, dtype=np.float64)

    def _fitness_legacy(self, sol: Solution, cost: float) -> float:
        f = float(cost)
        if self.layer_weight > 0.0:
            f += self.layer_weight * sol.distinct_layers_per_bin_full()
        return f

    # ---------------------------------------------------------------- pack
    def pack(
        self, prob: PackingProblem, init_pop: Sequence[Solution] | None = None
    ) -> PackingResult:
        rng = np.random.default_rng(self.seed)
        t0 = time.perf_counter()
        backend = self._resolve_backend()
        batched = backend in ("ref", "pallas")
        use_cache = backend != "legacy"
        pop: list[Solution] = [s.copy() for s in (init_pop or [])][: self.n_pop]
        pop += [
            nfd_from_scratch(
                prob,
                rng,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                sort_by_width=(k % 2 == 0),  # seed half the population width-aware
            )
            for k in range(len(pop), self.n_pop)
        ]
        if batched:
            # population geometry matrices: row i = per-bin (width, height) of
            # pop[i], zero-padded to the worst case of one buffer per bin
            W = np.zeros((self.n_pop, prob.n), dtype=np.int32)
            H = np.zeros((self.n_pop, prob.n), dtype=np.int32)
            for i, s in enumerate(pop):
                s.fill_geometry(W[i], H[i])
            costs = self._batched_costs(W, H, backend)
            fits = np.asarray(
                [fitness(s, self.layer_weight, cost=c) for s, c in zip(pop, costs)]
            )
        else:
            W = H = None
            if use_cache:
                costs = np.asarray([s.cost() for s in pop], dtype=np.float64)
                fits = np.asarray(
                    [fitness(s, self.layer_weight, cost=c) for s, c in zip(pop, costs)]
                )
            else:
                costs = np.asarray([s.cost_full() for s in pop], dtype=np.float64)
                fits = np.asarray(
                    [self._fitness_legacy(s, c) for s, c in zip(pop, costs)]
                )
        best_i = int(np.argmin(costs))
        best = pop[best_i].copy()
        best_cost = int(costs[best_i])
        trace = [(time.perf_counter() - t0, best_cost)]
        stale = 0
        gen = 0
        while gen < self.max_generations:
            gen += 1
            now = time.perf_counter() - t0
            if now > self.max_seconds or stale >= self.patience:
                break
            # --- mutation (mutated individuals are fresh objects; unmutated
            # ones may be shared references from selection, never mutated
            # in place)
            mutated: list[int] = []
            for i in range(self.n_pop):
                if rng.random() < self.p_mut:
                    pop[i] = self._mutate(pop[i], rng, use_cache=use_cache)
                    if batched:
                        pop[i].fill_geometry(W[i], H[i])
                        mutated.append(i)
                    elif use_cache:
                        costs[i] = pop[i].cost()
                        fits[i] = fitness(pop[i], self.layer_weight, cost=costs[i])
                    else:
                        costs[i] = pop[i].cost_full()
                        fits[i] = self._fitness_legacy(pop[i], costs[i])
            if batched and mutated:
                totals = self._batched_costs(W, H, backend)
                for i in mutated:
                    costs[i] = totals[i]
                    fits[i] = fitness(pop[i], self.layer_weight, cost=costs[i])
            # --- track best
            gi = int(np.argmin(costs))
            if int(costs[gi]) < best_cost:
                best_cost = int(costs[gi])
                best = pop[gi].copy()
                trace.append((time.perf_counter() - t0, best_cost))
                stale = 0
            else:
                stale += 1
            # --- tournament selection (with replacement) + elitism
            idx = rng.integers(self.n_pop, size=(self.n_pop, self.n_tour))
            winners = idx[np.arange(self.n_pop), np.argmin(fits[idx], axis=1)]
            winners[0] = int(np.argmin(fits))  # elitism: best survives
            pop = [pop[int(w)] for w in winners]
            costs = costs[winners]
            fits = fits[winners]
            if batched:
                W = W[winners]
                H = H[winners]
        wall = time.perf_counter() - t0
        trace.append((wall, best_cost))
        self.last_population_ = pop
        return PackingResult(
            solution=best,
            cost=best_cost,
            efficiency=best.efficiency(),
            wall_time_s=wall,
            algorithm=self.name + ("-intra" if self.intra_layer else ""),
            trace=trace,
            iterations=gen,
            params=dict(
                n_pop=self.n_pop,
                n_tour=self.n_tour,
                p_mut=self.p_mut,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                seed=self.seed,
                backend=backend,
            ),
        )


def _default_jax_backend() -> str:
    try:
        import jax

        return jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return "cpu"
