"""Genetic-algorithm memory packer — Algorithm 2 of the paper.

Bin-per-gene chromosome (Falkenauer encoding): an individual IS a packing
solution; each gene is one bin (a group of buffer indices).  There is no
crossover — as in the paper, mutation (buffer swap for GA-S, NFD repack for
GA-NFD) drives exploration, and tournament selection drives exploitation.
Fitness is the multi-objective weighted sum of BRAM cost and mean distinct
layers per bin (placement locality).

Evaluation backends (`GeneticPacker(backend=...)`):

* ``"python"`` — incremental scalar path: mutations carry per-bin record
  caches (see `Solution`), so evaluating a mutated individual is O(touched
  bins).
* ``"ref"`` / ``"pallas"`` — batched path: the population's bin geometry
  lives in padded ``(P, NB)`` int32 matrices updated in place from each
  mutation's dirty bins, and the whole generation's costs are computed in one
  `kernels.binpack_fitness.ops.population_costs` call (pure jnp on CPU,
  Pallas kernel on TPU).
* ``"auto"`` — ``pallas`` when a TPU is attached, else ``ref``.
* ``"legacy"`` — the seed's from-scratch scalar evaluation (no caches), kept
  as the benchmark baseline; identical RNG stream and results.

All backends are bit-identical for a fixed seed: cost arithmetic is exact
integer math and the RNG consumption order never depends on the backend.
The generation loop is factored into phase helpers over a `_GARun` state
(`_start_run` / `_mutation_phase` / `_apply_costs` / `_track_best` /
`_tournament`), which lets ``core.dse.pack_sweep`` drive many problems in
lockstep and stack their per-generation fitness into one
leading-problem-axis kernel call (docs/DESIGN.md section 10).

Heterogeneous OCM problems (``PackingProblem(ocm=...)``) add a RAM-kind
dimension: with probability ``p_kind`` a mutation reassigns random bins'
RAM kinds instead of moving buffers, fitness adds ``inventory_penalty`` per
unit of inventory overflow, and selection/best-tracking use the penalized
cost so a feasible packing always beats an overflowing one.  The batched
backends carry a parallel (P, NB) kind matrix through the per-kind-mode
``binpack_fitness`` tables.  Single-kind problems skip every hetero branch
(and its RNG draws), keeping the legacy streams bit-exact.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from .nfd import nfd_from_scratch, nfd_repack
from .problem import (
    DEFAULT_INVENTORY_PENALTY,
    PackingProblem,
    PackingResult,
    Solution,
)

BACKENDS = ("auto", "python", "ref", "pallas", "legacy")


def _apply_one_swap_move(
    bins: list[list[int]],
    prob: PackingProblem,
    src: int,
    dst: int,
    item_pick: int,
    swap_pick,
    intra_layer: bool,
    undo: list | None,
    touched: set | None,
) -> None:
    """Apply one already-drawn buffer-swap move to ``bins`` in place.

    ``item_pick`` indexes into the source bin; ``swap_pick`` is a callable
    returning the displaced-item index when the destination is full (so the
    draw only happens when the legacy RNG stream would make it).  Inverse
    ops are appended to ``undo``; touched bin indices are added to
    ``touched``.  The caller owns the geometry-cache bookkeeping.
    """
    layers = prob.layers_py
    src_bin = bins[src]
    item = src_bin[item_pick]
    dst_bin = bins[dst]
    if intra_layer and dst_bin and layers[dst_bin[0]] != layers[item]:
        return
    if len(dst_bin) >= prob.max_items:
        # swap instead of move to preserve cardinality feasibility
        j = swap_pick(len(dst_bin))
        other = dst_bin[j]
        if intra_layer and layers[other] != (
            layers[src_bin[0]] if src_bin else layers[item]
        ):
            return
        dst_bin[j] = item
        k = src_bin.index(item)
        src_bin[k] = other
        if undo is not None:
            undo.append((src, k, item, dst, j, other))
    else:
        k = src_bin.index(item)
        del src_bin[k]
        dst_bin.append(item)
        if undo is not None:
            undo.append((src, k, item, dst, -1, -1))
    if touched is not None:
        touched.add(src)
        touched.add(dst)


def _draw_other_kind(rng: np.random.Generator, old_k: int, n_kinds: int) -> int:
    """One RNG draw -> a uniformly random kind different from ``old_k``.

    Shared by the GA's ``kind_reassign`` and the SA move path inside
    ``apply_swap_moves`` so the two streams stay bit-identical by
    construction (the parity tests pin both)."""
    return (old_k + 1 + int(rng.integers(n_kinds - 1))) % n_kinds


def apply_swap_moves(
    sol: Solution,
    rng: np.random.Generator,
    n_moves: int = 1,
    intra_layer: bool = False,
    undo: list | None = None,
    touched: set | None = None,
    p_kind: float = 0.0,
) -> None:
    """Apply an MPack buffer-swap move sequence to ``sol.bins`` IN PLACE.

    Consumes ``rng`` in exactly the order the historical ``buffer_swap``
    did (the engine backend-parity tests pin trajectories on this stream).
    With ``p_kind > 0`` on a heterogeneous problem, each move is — with
    that probability — a RAM-kind reassignment of a random bin instead of
    a buffer swap (recorded in ``undo`` with the ``j == -2`` sentinel).
    ``p_kind == 0`` (the default, and the only value single-kind engines
    pass) draws nothing extra, preserving the legacy stream exactly.
    The geometry cache is NOT updated: callers either commit with
    ``sol.touch(*touched)`` + ``sol.drop_empty()`` or roll back with
    :func:`undo_swap_moves`.
    """
    bins = sol.bins
    prob = sol.problem
    n_kinds = prob.n_kinds
    kind_moves = p_kind > 0.0 and n_kinds > 1
    for _ in range(n_moves):
        if kind_moves and rng.random() < p_kind:
            bi = int(rng.integers(len(bins)))
            old_k = int(sol.kinds[bi])
            sol.kinds[bi] = _draw_other_kind(rng, old_k, n_kinds)
            if undo is not None:
                undo.append((bi, old_k, -1, -1, -2, -1))
            if touched is not None:
                touched.add(bi)
            continue
        if len(bins) < 2:
            break
        src = int(rng.integers(len(bins)))
        dst = int(rng.integers(len(bins)))
        if src == dst or not bins[src]:
            continue
        item_pick = int(rng.integers(len(bins[src])))
        _apply_one_swap_move(
            bins, prob, src, dst, item_pick,
            lambda n: int(rng.integers(n)), intra_layer, undo, touched,
        )


def undo_swap_moves(sol: Solution, undo: list) -> None:
    """Reverse a recorded move sequence, restoring exact bin contents/order
    (and kind lanes, for ``j == -2`` kind-reassignment entries)."""
    bins = sol.bins
    for src, k, item, dst, j, other in reversed(undo):
        if j == -2:
            sol.kinds[src] = k
        elif j < 0:
            bins[dst].pop()
            bins[src].insert(k, item)
        else:
            bins[dst][j] = other
            bins[src][k] = item


def buffer_swap(
    sol: Solution,
    rng: np.random.Generator,
    n_moves: int = 1,
    intra_layer: bool = False,
    p_kind: float = 0.0,
) -> Solution:
    """MPack-style perturbation: move random buffers between random bins.

    Reports every touched bin to the solution's record cache, so the child's
    ``cost()`` re-evaluates at most ``2 * n_moves`` bins.
    """
    out = sol.copy()
    touched: set[int] = set()
    apply_swap_moves(out, rng, n_moves=n_moves, intra_layer=intra_layer,
                     touched=touched, p_kind=p_kind)
    if touched:
        out.touch(*touched)
    out.drop_empty()
    return out


def kind_reassign(
    sol: Solution, rng: np.random.Generator, n_moves: int = 1
) -> Solution:
    """Heterogeneous mutation: move random bins to a random other RAM kind.

    The inventory penalty in the fitness turns this into directed pressure:
    reassignments that relieve an over-subscribed kind survive selection.
    Only meaningful on multi-kind problems (``problem.n_kinds > 1``).
    """
    out = sol.copy()
    n_kinds = out.problem.n_kinds
    touched: set[int] = set()
    for _ in range(n_moves):
        bi = int(rng.integers(len(out.bins)))
        out.kinds[bi] = _draw_other_kind(rng, int(out.kinds[bi]), n_kinds)
        touched.add(bi)
    out.touch(*touched)
    return out


def fitness(
    sol: Solution,
    layer_weight: float,
    cost: int | float | None = None,
    inventory_penalty: float = 0.0,
    overflow: int | None = None,
) -> float:
    """Weighted-sum fitness; pass a precomputed ``cost`` to avoid re-deriving it.

    ``inventory_penalty`` scales the unit-weighted inventory overflow
    (heterogeneous devices; zero and free on single-kind problems); pass a
    precomputed ``overflow`` to avoid re-deriving that too."""
    f = float(sol.cost() if cost is None else cost)
    if layer_weight > 0.0:
        f += layer_weight * sol.distinct_layers_per_bin()
    if inventory_penalty > 0.0:
        f += inventory_penalty * (
            sol.inventory_overflow() if overflow is None else overflow
        )
    return f


class GeneticPacker:
    def __init__(
        self,
        mutation: str = "nfd",  # "nfd" (GA-NFD) or "swap" (GA-S)
        n_pop: int = 50,
        n_tour: int = 5,
        p_mut: float = 0.4,
        p_adm_w: float = 0.0,
        p_adm_h: float = 0.1,
        nfd_threshold: float = 0.95,
        nfd_extra_frac: float = 0.01,
        nfd_max_bins: int = 12,
        swap_moves: int = 4,
        layer_weight: float = 0.01,
        intra_layer: bool = False,
        max_seconds: float = 60.0,
        max_generations: int = 100_000,
        patience: int = 200,
        seed: int = 0,
        backend: str = "auto",
        p_kind: float = 0.25,
        inventory_penalty: float = DEFAULT_INVENTORY_PENALTY,
    ):
        if mutation not in ("nfd", "swap"):
            raise ValueError(f"unknown mutation {mutation!r}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
        self.__dict__.update(locals())
        del self.__dict__["self"]
        # warm state for portfolio restarts (set after each pack())
        self.last_population_: list[Solution] | None = None

    @property
    def name(self) -> str:
        return "GA-NFD" if self.mutation == "nfd" else "GA-S"

    def _resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        try:
            import jax

            return "pallas" if jax.default_backend() == "tpu" else "ref"
        except Exception:
            return "python"

    def _mutate(
        self,
        sol: Solution,
        rng: np.random.Generator,
        use_cache: bool = True,
        hetero: bool = False,
    ) -> Solution:
        # heterogeneous OCM: a fraction of mutations reassign RAM kinds
        # instead of moving buffers (the gate is skipped entirely — no RNG
        # draw — on single-kind problems, pinning the legacy stream)
        if hetero and rng.random() < self.p_kind:
            return kind_reassign(sol, rng)
        if self.mutation == "nfd":
            return nfd_repack(
                sol,
                rng,
                threshold=self.nfd_threshold,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                extra_frac=self.nfd_extra_frac,
                max_bins=self.nfd_max_bins,
                use_cache=use_cache,
            )
        return buffer_swap(
            sol, rng, n_moves=self.swap_moves, intra_layer=self.intra_layer
        )

    # ---------------------------------------------------------------- eval
    @staticmethod
    def _batched_costs(
        W: np.ndarray,
        H: np.ndarray,
        backend: str,
        Km: np.ndarray | None = None,
        kind_tables=None,
        modes=None,
        mesh=None,
    ) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels.binpack_fitness.ops import population_costs

        interpret = backend == "pallas" and _default_jax_backend() != "tpu"
        if Km is None:
            # single-kind: the problem's own mode table (equal to
            # BRAM18_MODES on default problems, so the jit cache is shared)
            totals = population_costs(
                jnp.asarray(W), jnp.asarray(H), modes=modes,
                backend=backend, interpret=interpret, mesh=mesh,
            )
        else:
            totals = population_costs(
                jnp.asarray(W),
                jnp.asarray(H),
                backend=backend,
                interpret=interpret,
                kinds=jnp.asarray(Km),
                kind_tables=kind_tables,
                mesh=mesh,
            )
        return np.asarray(totals, dtype=np.float64)

    def _fitness_legacy(self, sol: Solution, cost: float, hetero: bool) -> float:
        f = float(cost)
        if self.layer_weight > 0.0:
            f += self.layer_weight * sol.distinct_layers_per_bin_full()
        if hetero and self.inventory_penalty > 0.0:
            f += self.inventory_penalty * sol.inventory_overflow()
        return f

    # ---------------------------------------------------------------- pack
    #
    # The generation loop is split into phase helpers operating on a `_GARun`
    # state object so that `core.dse`'s lockstep sweep driver can interleave
    # many problems' generations and stack their fitness evaluations into one
    # leading-problem-axis `binpack_fitness` call, while `pack()` below
    # reassembles the exact same single-problem loop (the backend-parity
    # tests in tests/test_engine.py pin that this refactor changed nothing).

    def _start_run(
        self,
        prob: PackingProblem,
        rng: np.random.Generator,
        init_pop: Sequence[Solution] | None,
        backend: str,
    ) -> "_GARun":
        """Build one problem's population + evaluation matrices (no RNG
        draws beyond the population init itself)."""
        run = _GARun()
        run.prob = prob
        run.rng = rng
        run.t0 = time.perf_counter()
        run.backend = backend
        run.batched = backend in ("ref", "pallas")
        run.use_cache = backend != "legacy"
        run.hetero = prob.n_kinds > 1
        run.inv_pen = self.inventory_penalty if run.hetero else 0.0
        run.modes0 = prob.kind_tables[0][1]  # == BRAM18_MODES on defaults
        pop: list[Solution] = [s.copy() for s in (init_pop or [])][: self.n_pop]
        pop += [
            nfd_from_scratch(
                prob,
                rng,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                sort_by_width=(k % 2 == 0),  # seed half the population width-aware
            )
            for k in range(len(pop), self.n_pop)
        ]
        run.pop = pop
        # on heterogeneous problems selection AND best-tracking use the
        # inventory-penalized cost, so an overflowing packing can never beat
        # a feasible one; ``ovfs`` mirrors ``costs`` per individual
        run.ovfs = np.zeros(self.n_pop, dtype=np.float64) if run.hetero else None
        if run.batched:
            # population geometry matrices: row i = per-bin (width, height) of
            # pop[i], zero-padded to the worst case of one buffer per bin
            run.W = np.zeros((self.n_pop, prob.n), dtype=np.int32)
            run.H = np.zeros((self.n_pop, prob.n), dtype=np.int32)
            # heterogeneous problems add a parallel RAM-kind matrix
            run.Km = (
                np.zeros((self.n_pop, prob.n), dtype=np.int32)
                if run.hetero
                else None
            )
            run.kt = prob.kind_tables if run.hetero else None
            for i, s in enumerate(pop):
                s.fill_geometry(run.W[i], run.H[i])
                if run.Km is not None:
                    s.fill_kinds(run.Km[i])
        else:
            run.W = run.H = run.Km = None
            run.kt = None
        if run.ovfs is not None:
            for i, s in enumerate(pop):
                run.ovfs[i] = s.inventory_overflow()
        return run

    def _eval_init(self, run: "_GARun", totals=None) -> None:
        """Initial population evaluation; ``totals`` carries the batched
        kernel costs (the lockstep driver computes them stacked)."""
        if run.batched:
            costs = np.asarray(totals, dtype=np.float64)
            fits = np.asarray(
                [
                    fitness(s, self.layer_weight, cost=c,
                            inventory_penalty=run.inv_pen,
                            overflow=None if run.ovfs is None else run.ovfs[i])
                    for i, (s, c) in enumerate(zip(run.pop, costs))
                ]
            )
        elif run.use_cache:
            costs = np.asarray([s.cost() for s in run.pop], dtype=np.float64)
            fits = np.asarray(
                [
                    fitness(s, self.layer_weight, cost=c,
                            inventory_penalty=run.inv_pen,
                            overflow=None if run.ovfs is None else run.ovfs[i])
                    for i, (s, c) in enumerate(zip(run.pop, costs))
                ]
            )
        else:
            costs = np.asarray([s.cost_full() for s in run.pop], dtype=np.float64)
            fits = np.asarray(
                [
                    self._fitness_legacy(s, c, run.hetero)
                    for s, c in zip(run.pop, costs)
                ]
            )
        run.costs = costs
        run.fits = fits
        sel = costs if run.ovfs is None else costs + run.inv_pen * run.ovfs
        best_i = int(np.argmin(sel))
        run.best = run.pop[best_i].copy()
        run.best_cost = int(costs[best_i])
        run.best_sel = float(sel[best_i])
        # hetero traces record the penalized cost (the annealed/selected
        # quantity) so the curve stays monotone; raw == penalized otherwise
        run.trace = [(time.perf_counter() - run.t0,
                      run.best_sel if run.hetero else run.best_cost)]
        run.stale = 0
        run.gen = 0

    def _mutation_phase(self, run: "_GARun") -> list[int]:
        """One generation's mutations (mutated individuals are fresh objects;
        unmutated ones may be shared references from selection, never mutated
        in place).  Returns the mutated indices; on the batched path their
        kernel costs are applied afterwards via `_apply_costs`."""
        mutated: list[int] = []
        for i in range(self.n_pop):
            if run.rng.random() < self.p_mut:
                run.pop[i] = self._mutate(
                    run.pop[i], run.rng, use_cache=run.use_cache,
                    hetero=run.hetero,
                )
                if run.ovfs is not None:
                    run.ovfs[i] = run.pop[i].inventory_overflow()
                if run.batched:
                    run.pop[i].fill_geometry(run.W[i], run.H[i])
                    if run.Km is not None:
                        run.pop[i].fill_kinds(run.Km[i])
                    mutated.append(i)
                elif run.use_cache:
                    run.costs[i] = run.pop[i].cost()
                    run.fits[i] = fitness(
                        run.pop[i], self.layer_weight, cost=run.costs[i],
                        inventory_penalty=run.inv_pen,
                        overflow=None if run.ovfs is None else run.ovfs[i],
                    )
                else:
                    run.costs[i] = run.pop[i].cost_full()
                    run.fits[i] = self._fitness_legacy(
                        run.pop[i], run.costs[i], run.hetero
                    )
        return mutated

    def _apply_costs(self, run: "_GARun", totals, mutated: list[int]) -> None:
        for i in mutated:
            run.costs[i] = totals[i]
            run.fits[i] = fitness(
                run.pop[i], self.layer_weight, cost=run.costs[i],
                inventory_penalty=run.inv_pen,
                overflow=None if run.ovfs is None else run.ovfs[i],
            )

    def _track_best(self, run: "_GARun") -> None:
        # --- track best (penalized on heterogeneous problems)
        sel = (
            run.costs
            if run.ovfs is None
            else run.costs + run.inv_pen * run.ovfs
        )
        gi = int(np.argmin(sel))
        if float(sel[gi]) < run.best_sel:
            run.best_sel = float(sel[gi])
            run.best_cost = int(run.costs[gi])
            run.best = run.pop[gi].copy()
            run.trace.append((time.perf_counter() - run.t0,
                              run.best_sel if run.hetero else run.best_cost))
            run.stale = 0
        else:
            run.stale += 1

    def _tournament(self, run: "_GARun") -> None:
        # --- tournament selection (with replacement) + elitism
        idx = run.rng.integers(self.n_pop, size=(self.n_pop, self.n_tour))
        winners = idx[np.arange(self.n_pop), np.argmin(run.fits[idx], axis=1)]
        winners[0] = int(np.argmin(run.fits))  # elitism: best survives
        run.pop = [run.pop[int(w)] for w in winners]
        run.costs = run.costs[winners]
        run.fits = run.fits[winners]
        if run.ovfs is not None:
            run.ovfs = run.ovfs[winners]
        if run.batched:
            run.W = run.W[winners]
            run.H = run.H[winners]
            if run.Km is not None:
                run.Km = run.Km[winners]

    def _finish_run(self, run: "_GARun") -> PackingResult:
        wall = time.perf_counter() - run.t0
        run.trace.append((wall, run.best_sel if run.hetero else run.best_cost))
        self.last_population_ = run.pop
        extra = (
            dict(p_kind=self.p_kind, inventory_penalty=self.inventory_penalty,
                 overflow=run.best.inventory_overflow())
            if run.hetero
            else {}
        )
        return PackingResult(
            solution=run.best,
            cost=run.best_cost,
            efficiency=run.best.efficiency(),
            wall_time_s=wall,
            algorithm=self.name + ("-intra" if self.intra_layer else ""),
            trace=run.trace,
            iterations=run.gen,
            params=dict(
                n_pop=self.n_pop,
                n_tour=self.n_tour,
                p_mut=self.p_mut,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                seed=self.seed,
                backend=run.backend,
                **extra,
            ),
        )

    def _migrate_in(self, run: "_GARun", sol: Solution) -> bool:
        """Portfolio barrier hook: the migrant replaces this run's worst
        individual (by penalized selection cost) iff strictly better.  A
        finished run is never touched and ``stale`` is never reset, so
        migration cannot revive a converged island."""
        if run.done or run.stale >= self.patience:
            return False
        sel = (
            run.costs
            if run.ovfs is None
            else run.costs + run.inv_pen * run.ovfs
        )
        worst = int(np.argmax(sel))
        cost = float(sol.cost())
        ovf = float(sol.inventory_overflow()) if run.ovfs is not None else 0.0
        mig_sel = cost + run.inv_pen * ovf
        if mig_sel >= float(sel[worst]):
            return False
        mig = sol.copy()
        run.pop[worst] = mig
        run.costs[worst] = cost
        if run.ovfs is not None:
            run.ovfs[worst] = ovf
        run.fits[worst] = fitness(
            mig, self.layer_weight, cost=cost, inventory_penalty=run.inv_pen,
            overflow=None if run.ovfs is None else ovf,
        )
        if run.batched:
            mig.fill_geometry(run.W[worst], run.H[worst])
            if run.Km is not None:
                mig.fill_kinds(run.Km[worst])
        # fold the migrant into the best-tracking reference (no trace entry,
        # no stale reset): otherwise the next _track_best would record the
        # migrant as this run's own improvement and revive its patience
        if mig_sel < run.best_sel:
            run.best_sel = mig_sel
            run.best_cost = int(cost)
            run.best = mig.copy()
        return True

    # ------------------------------------------------- portfolio racing hooks
    def _extend_run(self, run: "_GARun", gen_limit: int) -> None:
        """Racing budget reallocation: raise this run's generation budget to
        at least ``gen_limit``, reviving a run that stopped *on budget*
        (never one converged on patience or cut by the wall cap) — the GA
        half of the ledger contract in ``portfolio.pack_portfolio(auto=True)``.
        """
        if run.done and run.stale < self.patience and run.gen >= self.max_generations:
            run.done = False
        self.max_generations = max(self.max_generations, int(gen_limit))

    def _eliminate_run(self, run: "_GARun") -> None:
        """Racing elimination: stop this run forever.  ``lockstep_begin``
        skips done runs before any mutation draw, so the lockstep pack's
        surviving runs consume exactly the RNG streams they would have
        without this island."""
        run.done = True

    def pack(
        self, prob: PackingProblem, init_pop: Sequence[Solution] | None = None
    ) -> PackingResult:
        rng = np.random.default_rng(self.seed)
        backend = self._resolve_backend()
        run = self._start_run(prob, rng, init_pop, backend)
        totals = (
            self._batched_costs(run.W, run.H, backend, run.Km, run.kt, run.modes0)
            if run.batched
            else None
        )
        self._eval_init(run, totals)
        while run.gen < self.max_generations:
            run.gen += 1
            now = time.perf_counter() - run.t0
            if now > self.max_seconds or run.stale >= self.patience:
                break
            mutated = self._mutation_phase(run)
            if run.batched and mutated:
                totals = self._batched_costs(
                    run.W, run.H, backend, run.Km, run.kt, run.modes0
                )
                self._apply_costs(run, totals, mutated)
            self._track_best(run)
            self._tournament(run)
        return self._finish_run(run)


def stack_geometry(runs: Sequence["_GARun"]):
    """Stack several runs' ``(n_pop, NB_j)`` geometry (and kind) matrices
    into one zero-padded ``(A, n_pop, NB_max)`` block.

    Padded lanes have width 0 and cost nothing, so leading-problem-axis
    totals equal the per-run 2-D fitness calls exactly.  Returns
    ``(W, H, Km)`` with ``Km is None`` on single-kind problems."""
    nb = max(r.W.shape[1] for r in runs)
    n_pop = runs[0].W.shape[0]
    W = np.zeros((len(runs), n_pop, nb), dtype=np.int32)
    H = np.zeros_like(W)
    hetero = runs[0].Km is not None
    Km = np.zeros_like(W) if hetero else None
    for a, r in enumerate(runs):
        W[a, :, : r.W.shape[1]] = r.W
        H[a, :, : r.H.shape[1]] = r.H
        if hetero:
            Km[a, :, : r.Km.shape[1]] = r.Km
    return W, H, Km


def stacked_population_costs(
    runs: Sequence["_GARun"], backend: str, mesh=None
) -> np.ndarray:
    """One leading-problem-axis fitness call over several GA runs (see
    :func:`stack_geometry` for the padding contract).  Shared by
    ``core.dse``'s sweep driver (many problems, one packer) and
    ``core.portfolio``'s island driver (one problem, many packers).
    ``mesh`` (a ``("prob",)`` sweep mesh) row-shards the stacked call.
    """
    W, H, Km = stack_geometry(runs)
    return GeneticPacker._batched_costs(
        W, H, backend, Km, runs[0].kt, runs[0].modes0, mesh=mesh
    )


def lockstep_begin(
    pairs: Sequence[tuple[GeneticPacker, "_GARun"]],
    gen_limit: int | None = None,
) -> tuple[list, list]:
    """Segment phase 1 of one lockstep generation: per-run bookkeeping
    (budget/patience/wall checks) plus the mutation phase.

    Returns ``(advanced, batches)``: ``advanced`` is the live ``(packer,
    run)`` pairs that entered this generation, ``batches`` the pending
    fitness work as lists of ``(packer, run, mutated)`` entries grouped by
    population size — each batch is one stacked leading-problem-axis
    fitness call (see :func:`stack_geometry`).  Callers evaluate every
    batch (directly via :func:`stacked_population_costs`, or fused with SA
    fleet work through ``binpack_portfolio_step``), feed the totals to
    :func:`lockstep_apply`, then close the generation with
    :func:`lockstep_finish`.  ``gen_limit`` *pauses* runs that reached a
    portfolio barrier without marking them done."""
    advanced: list[tuple[GeneticPacker, _GARun]] = []
    pending: list[tuple[GeneticPacker, _GARun, list[int]]] = []
    for packer, run in pairs:
        if run.done:
            continue
        if gen_limit is not None and run.gen >= gen_limit:
            continue
        if run.gen >= packer.max_generations:
            run.done = True
            continue
        run.gen += 1
        now = time.perf_counter() - run.t0
        if now > packer.max_seconds or run.stale >= packer.patience:
            run.done = True
            continue
        mutated = packer._mutation_phase(run)
        advanced.append((packer, run))
        if run.batched and mutated:
            pending.append((packer, run, mutated))
    groups: dict[int, list] = {}
    for entry in pending:
        groups.setdefault(entry[1].W.shape[0], []).append(entry)
    return advanced, list(groups.values())


def lockstep_apply(batch: Sequence[tuple], totals) -> None:
    """Segment phase 2: land one batch's stacked fitness totals (row ``a``
    of ``totals`` belongs to ``batch[a]``'s run)."""
    for (packer, run, mutated), tot in zip(batch, totals):
        packer._apply_costs(run, tot, mutated)


def lockstep_finish(advanced: Sequence[tuple]) -> bool:
    """Segment phase 3: best tracking + tournament selection for every pair
    that advanced; returns True while any pair advanced."""
    for packer, run in advanced:
        packer._track_best(run)
        packer._tournament(run)
    return bool(advanced)


def lockstep_generation(
    pairs: Sequence[tuple[GeneticPacker, "_GARun"]],
    gen_limit: int | None = None,
    mesh=None,
) -> bool:
    """Advance ONE generation for every live (packer, run) pair in lockstep.

    All batched pairs' mutated populations are evaluated in stacked
    leading-problem-axis fitness calls (grouped by population size, via
    :func:`stacked_population_costs`); each run consumes only its own RNG
    stream, so every trajectory is bit-identical to the standalone
    ``pack()`` loop.  ``gen_limit`` *pauses* runs that have reached a
    portfolio barrier without marking them done; budget/patience/wall
    exhaustion marks ``run.done``.  Returns True while any pair advanced.
    (A thin driver over the segment phases :func:`lockstep_begin` /
    :func:`lockstep_apply` / :func:`lockstep_finish`.)  ``mesh`` row-shards
    each stacked fitness call over a ``("prob",)`` sweep mesh (PR 8) —
    bit-identical, jax backends only.
    """
    advanced, batches = lockstep_begin(pairs, gen_limit)
    for batch in batches:
        totals = stacked_population_costs(
            [r for _, r, _ in batch], batch[0][1].backend, mesh=mesh
        )
        lockstep_apply(batch, totals)
    return lockstep_finish(advanced)


class _GARun:
    """One problem's GA state, advanced generation-wise by the phase helpers
    of `GeneticPacker` (its own `pack()` loop, `core.dse`'s lockstep
    multi-problem driver, or `core.portfolio`'s island driver — all through
    :func:`lockstep_generation`-compatible phases).

    ``CODEC_*`` is the serialization contract consumed by ``core.resume``:
    ``costs``/``fits`` (and ``ovfs`` on heterogeneous problems) land in a
    checkpoint's ``arrays.npz``; the scalars, RNG state, population, best
    solution, and trace in its JSON manifest.  The geometry matrices
    ``W``/``H``/``Km`` are refilled from the restored population, and
    shared-reference aliasing inside ``pop`` (tournament winners) need not
    survive serialization: mutation always replaces ``pop[i]`` with a fresh
    object, never edits one in place.
    """

    CODEC_ARRAYS = ("costs", "fits")
    CODEC_ARRAYS_HETERO = ("ovfs",)
    CODEC_SCALARS = ("best_cost", "best_sel", "gen", "stale", "done")

    __slots__ = (
        "prob", "rng", "t0", "backend", "batched", "use_cache", "hetero",
        "inv_pen", "modes0", "kt", "pop", "costs", "fits", "ovfs",
        "W", "H", "Km", "best", "best_cost", "best_sel", "trace",
        "stale", "gen", "done",
    )

    def __init__(self):
        self.done = False


def _default_jax_backend() -> str:
    try:
        import jax

        return jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return "cpu"
