"""Next-Fit Dynamic (NFD) — Algorithm 1 of the paper.

NFD is a *repacking* heuristic: it selects poorly-mapping bins (BRAM mapping
efficiency below a threshold), decomposes them into their constituent
buffers, shuffles, and repacks next-fit style.  The open bin grows only when
adding the buffer shrinks the wasted depth on the BRAM grid (``new_gap <
gap``) and the widths align — each check can be probabilistically overridden
(``p_adm_h`` / ``p_adm_w``) to let the surrounding GA/SA explore.

As a *mutation operator* inside GA/SA the repack is kept local: only the
``max_bins`` worst-mapping bins (plus a random exploration subset) are
decomposed per call, so one mutation is a small, cheap move rather than a
global restart.  A full-problem pass (``nfd_from_scratch``) is used for
population initialization.
"""
from __future__ import annotations

import numpy as np

from .problem import PackingProblem, Solution, greedy_assign_kinds


def nfd_pack_order(
    prob: PackingProblem,
    order,
    rng: np.random.Generator,
    p_adm_w: float = 0.0,
    p_adm_h: float = 0.1,
    intra_layer: bool = False,
) -> list[list[int]]:
    """Pack buffers in the given order with the NFD admission rule.

    Returns a list of bins (lists of buffer indices).  O(len(order)).
    """
    bins: list[list[int]] = []
    cur: list[int] = []
    cur_w = 0
    cur_h = 0
    cur_layer = -1
    widths, depths, layers = prob.widths_py, prob.depths_py, prob.layers_py
    max_items = prob.max_items
    cmg = prob._cost_mode_gap
    rand = rng.random
    for i in order:
        i = int(i)
        w, d = widths[i], depths[i]
        if not cur:
            cur = [i]
            cur_w, cur_h, cur_layer = w, d, layers[i]
            continue
        new_w = cur_w if cur_w >= w else w
        new_h = cur_h + d
        ok = (
            len(cur) < max_items
            and (cmg(new_w, new_h)[2] < cmg(cur_w, cur_h)[2] or rand() < p_adm_h)
            and (cur_w == w or rand() < p_adm_w)
            and (not intra_layer or layers[i] == cur_layer)
        )
        if ok:
            cur.append(i)
            cur_w, cur_h = new_w, new_h
        else:
            bins.append(cur)
            cur = [i]
            cur_w, cur_h, cur_layer = w, d, layers[i]
    if cur:
        bins.append(cur)
    return bins


def select_repack_bins(
    sol: Solution,
    rng: np.random.Generator,
    threshold: float,
    max_bins: int,
    extra_frac: float,
    use_cache: bool = True,
) -> np.ndarray:
    """Boolean mask of bins to decompose: worst-efficiency first (below the
    threshold), capped at ``max_bins``, plus a random exploration subset."""
    eff = sol.bin_efficiencies() if use_cache else sol.bin_efficiencies_full()
    n = len(eff)
    mask = np.zeros(n, dtype=bool)
    below = np.flatnonzero(eff < threshold)
    if len(below) > max_bins:
        # cap: take the worst max_bins of them, randomized among ties
        below = below[np.argsort(eff[below] + 1e-9 * rng.random(len(below)))][:max_bins]
    mask[below] = True
    if extra_frac > 0.0:
        mask |= rng.random(n) < extra_frac
    if not mask.any():
        mask[rng.integers(n)] = True
    return mask


def nfd_repack(
    sol: Solution,
    rng: np.random.Generator,
    threshold: float = 0.95,
    p_adm_w: float = 0.0,
    p_adm_h: float = 0.1,
    intra_layer: bool = False,
    extra_frac: float = 0.0,
    max_bins: int = 12,
    use_cache: bool = True,
) -> Solution:
    """Algorithm 1 as a local mutation: decompose selected bins and repack.

    Kept bins carry their cached records into the child solution, so the
    child's ``cost()`` only evaluates the freshly repacked bins.  Passing
    ``use_cache=False`` reproduces the seed's from-scratch evaluation
    behaviour (same RNG stream, same result) for benchmarking.
    """
    prob = sol.problem
    mask = select_repack_bins(
        sol, rng, threshold, max_bins, extra_frac, use_cache=use_cache
    )
    keep = [b for b, m in zip(sol.bins, mask) if not m]
    pool = np.asarray(
        [i for b, m in zip(sol.bins, mask) if m for i in b], dtype=np.int64
    )
    rng.shuffle(pool)
    if intra_layer:
        # stable sort by layer after the shuffle: random order within a layer,
        # layers contiguous, so next-fit never straddles a layer boundary for
        # long runs (the layer check still enforces correctness).
        pool = pool[np.argsort(prob.layers[pool], kind="stable")]
    new_bins = nfd_pack_order(
        prob, pool, rng, p_adm_w=p_adm_w, p_adm_h=p_adm_h, intra_layer=intra_layer
    )
    # kept bins carry their RAM kinds into the child; freshly repacked bins
    # start on kind 0 (the finest-grained primitive) — the engines' kind
    # moves and inventory penalty re-balance them
    if not use_cache:
        if prob.n_kinds == 1:
            return Solution(prob, keep + new_bins)
        kept_kinds = [int(k) for k, m in zip(sol.kinds, mask) if not m]
        return Solution(
            prob, keep + new_bins, kinds=kept_kinds + [0] * len(new_bins)
        )
    # Kept bin lists are SHARED with the parent (persistent-structure style):
    # nothing in the engine mutates a bin list without copying the solution
    # first (buffer_swap works on a fresh copy()), so sharing is safe and
    # avoids an O(n) deep copy per mutation.  new_bins are fresh lists and
    # their geometry rows start dirty.
    nk, nn = len(keep), len(new_bins)
    geom = np.empty((nk + nn, 6), dtype=np.int64)
    geom[:nk] = sol._geom[~mask]
    dirty = np.empty(nk + nn, dtype=bool)
    dirty[:nk] = sol._dirty[~mask]
    dirty[nk:] = True
    kinds = np.zeros(nk + nn, dtype=np.int64)
    kinds[:nk] = sol.kinds[~mask]
    return Solution._with_geometry(prob, keep + new_bins, geom, dirty, kinds)


def nfd_from_scratch(
    prob: PackingProblem,
    rng: np.random.Generator,
    p_adm_w: float = 0.0,
    p_adm_h: float = 0.1,
    intra_layer: bool = False,
    sort_by_width: bool = False,
) -> Solution:
    """One NFD pass over all buffers in random order (used for GA/SA init).

    ``sort_by_width`` groups same-width buffers adjacently (random order
    within a width class) — a width-aware seeding that the admission rule
    then exploits; initial populations mix both orderings for diversity.
    """
    order = rng.permutation(prob.n)
    if sort_by_width:
        order = order[np.argsort(prob.widths[order], kind="stable")]
    if intra_layer:
        order = order[np.argsort(prob.layers[order], kind="stable")]
    sol = Solution(
        prob,
        nfd_pack_order(
            prob, order, rng, p_adm_w=p_adm_w, p_adm_h=p_adm_h, intra_layer=intra_layer
        ),
    )
    # heterogeneous devices: start from an inventory-feasible kind lane
    # (deterministic, no RNG draws; no-op on single-kind problems)
    return greedy_assign_kinds(sol)
