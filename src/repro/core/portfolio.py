"""Multi-seed island portfolio: a deterministic fleet of GA/SA islands.

The paper's hybrid mappers are stochastic — different seeds land on
different local optima.  A *portfolio* run hedges that variance: K islands
(differently-seeded GA/SA instances, possibly with different algorithms or
hyperparameters) evolve on one problem and periodically exchange their best
packing, so good building blocks spread without collapsing diversity.

The portfolio is **fleet-native and iteration-budgeted** — an array
program, not a thread pool:

* Every multi-chain ``sa-s`` island rides the SA fleet core
  (`SimulatedAnnealingPacker._anneal_block`): K same-problem islands are a
  ``P = K`` fleet with problem-major rows and one ``np.random.Generator``
  stream per island, exactly the layout ``core.dse.pack_sweep`` uses for
  cross-problem sweeps — here the "problems" are replicas of one problem.
* GA islands advance generation-by-generation through the `_GARun` phase
  helpers (`ga.lockstep_generation`), stacking every island's population
  fitness into one leading-axis ``(K, n_pop, NB)`` kernel call.
* Scalar engines (``sa-nfd``'s sequential NFD repack, single-chain
  ``sa-s``, ``legacy`` backends) run their own resumable loops — the same
  code path their standalone ``pack()`` uses, advanced in segments.
* **Migration is a deterministic array exchange at fixed barriers**: every
  ``migration_every`` iterations (SA steps) / generations (GA), the global
  best solution is broadcast into each *other* island's worst warm slot
  (worst chain / worst individual / the incumbent), iff strictly better
  under the inventory-penalized cost.  Migration never touches patience
  counters, so it can never revive a frozen island — a frozen island stops
  drawing RNG exactly where its standalone run would.

Because islands advance by iteration counts and each consumes only its own
seeded RNG stream, ``pack_portfolio(prob, seed=s, ...)`` is **bit-
reproducible** run-to-run and machine-independent (given iteration budgets;
``max_seconds`` remains as an outer safety cap only), and a single-island
portfolio is bit-identical to the corresponding standalone ``pack()`` run —
both pinned in ``tests/test_portfolio.py``.  Barrier semantics and the
seed/stream layout: docs/DESIGN.md section 11; the concurrent scheduler,
per-family strides, and fused dispatch: section 13 (parity pins in
``tests/test_portfolio_concurrent.py``).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

import numpy as np

from .dse import _shard_devices, shard_chunks
from .ga import (
    GeneticPacker,
    lockstep_apply,
    lockstep_begin,
    lockstep_finish,
    lockstep_generation,
    stack_geometry,
    stacked_population_costs,
)
from .problem import (
    DEFAULT_INVENTORY_PENALTY,
    PackingProblem,
    PackingResult,
    Solution,
    decode_chain_items,
)
from .sa import SimulatedAnnealingPacker

# default barrier spacing: SA iterations / GA generations between migrations
DEFAULT_MIGRATION_EVERY = 64

# Per-engine-family barrier strides on heterogeneous lineups (>1 engine
# group): one barrier advances the delta-kernel SA engines (fleet and
# single-chain sa-s) ``migration_every`` annealing steps — scaled up by the
# number of GA islands in the lineup, see below — the scalar loops (sa-nfd's
# sequential repack, the legacy backend) a quarter of that base, and the GA
# lockstep pack 1/32 of it in generations.  The divisors are static
# constants — strides depend only on the lineup and ``migration_every``,
# never on machine speed — so trajectories stay bit-reproducible; they exist
# because one GA generation (n_pop mutation repacks + a stacked fitness
# call) costs on the order of `_GA_STRIDE_DIV` vectorized fleet steps *per
# GA island*, and a uniform stride would park the whole barrier on the
# slowest family (the ISSUE-7 "mixed lineup 0.24x threads" pathology).  The
# GA-island multiplier lets the vectorized engines absorb the barrier slack
# instead of idling while a stacked generation finishes.  Homogeneous
# lineups (a single engine group) keep the uniform stride: nothing to
# rebalance, and the fleet path stays exactly PR 5's.
_SCALAR_STRIDE_DIV = 4
_GA_STRIDE_DIV = 32

# Racing ledger currency (``pack_portfolio(auto=True)``): one unit is one
# chain-annealing step.  A fleet island burns ``stride * n_chains`` units per
# barrier, a scalar/single-chain island ``stride``, and a GA island
# ``stride * n_pop * _GA_GEN_WORK`` — one generation mutates and re-evaluates
# on the order of ``n_pop`` individuals, and the stride design above prices a
# default generation (n_pop=50) at ``_GA_STRIDE_DIV`` fleet steps of
# ``sa_chains=8`` chains, i.e. 32*8/50 ~ 5 chain-steps per individual.  The
# weights are static functions of the lineup, so the ledger — and with it
# every elimination decision — is machine-independent.
_GA_GEN_WORK = 5

# Default race grid for ``pack_portfolio(auto=True)``: the hyperparameter
# axes the paper shows the mappers are sensitive to — GA population size and
# mutation rate (Fig. 4/5, reproduced in ``benchmarks/bench_fig45.py``), SA
# chain counts, temperature ladders, and move widths (Table 2 neighborhood).
# Entries are ``(algorithm, hyper-overrides)``; island k races with seed
# ``seed + k``.
DEFAULT_RACE_GRID = (
    ("sa-s", {}),
    ("sa-s", {"n_chains": 16, "ladder_max": 8.0}),
    ("sa-s", {"n_chains": 4, "ladder_min": 0.25, "ladder_max": 1.0}),
    ("sa-s", {"sa_t0": 60.0, "sa_rc": 0.5}),
    ("sa-s", {"sa_t0": 10.0, "sa_rc": 2.0}),
    ("sa-s", {"swap_moves": 4}),
    ("ga-nfd", {}),
    ("ga-nfd", {"n_pop": 25, "p_mut": 0.6}),
    ("ga-nfd", {"n_pop": 150}),
    ("ga-nfd", {"n_pop": 5, "p_mut": 0.8}),
    ("ga-s", {"n_pop": 25}),
    ("sa-nfd", {}),
)

# offset between per-round reseeds of the legacy thread-pool portfolio; any
# large odd constant keeps island streams disjoint from the base seeds
_ROUND_SEED_STRIDE = 7919


class TruncationWarning(RuntimeWarning):
    """A wall-clock cap cut a run short of its iteration/patience budgets —
    the result is NOT seed-reproducible across machines.  Promoted to an
    error in the test suite (``pytest.ini``); tests that intentionally
    exercise the truncation path catch it with ``pytest.warns``."""


@dataclasses.dataclass(frozen=True)
class IslandSpec:
    """One island: which packer, which base seed, which overrides."""

    algorithm: str = "ga-nfd"
    seed: int = 0
    hyper: dict = dataclasses.field(default_factory=dict)


# --------------------------------------------------------------- island views
class _SAFleetGroup:
    """K same-problem sa-s islands advanced as ONE `_anneal_block` fleet.

    Row ``j * C + c`` is chain ``c`` of island ``j``; the bin-slot envelope
    is widened to ``prob.n`` so any migrant packing can be encoded into a
    chain slot (envelope padding never affects trajectories — DESIGN.md
    section 10).

    ``n_shards`` splits the islands into contiguous sub-fleets, one block
    state per shard, advanced concurrently on threads at every barrier;
    ``mesh`` row-shards each fleet step over a ``("prob",)`` device mesh
    (with one shard) or pins the sub-fleets round-robin to the mesh's
    devices (with several).  Both are pure execution-shape knobs: each
    island consumes only its own RNG stream, so any shard count is
    bit-identical to the one-fleet layout (docs/DESIGN.md section 14,
    pinned in ``tests/test_sharded.py``)."""

    def __init__(self, packer, prob, rngs, backend, n_shards=1, mesh=None):
        self.packer = packer
        chunks = shard_chunks(len(rngs), n_shards)
        shard_mesh = mesh if len(chunks) == 1 else None
        self.devices = _shard_devices(mesh, len(chunks), backend)
        self.sts = [
            packer._block_start(
                [prob] * len(c), [rngs[j] for j in c], [[] for _ in c],
                backend, n_slots=prob.n, mesh=shard_mesh,
            )
            for c in chunks
        ]
        self._starts = [c[0] for c in chunks]

    @property
    def st(self):
        """The lone block state of an unsharded fleet (the common case and
        the fused-dispatch requirement); multi-shard fleets have no single
        state — address islands through :meth:`state_of`."""
        if len(self.sts) != 1:
            raise RuntimeError(
                f"fleet is split into {len(self.sts)} shards; use state_of(j)"
            )
        return self.sts[0]

    def state_of(self, j: int):
        """(block state, local row) owning island ``j``."""
        for st, lo in zip(reversed(self.sts), reversed(self._starts)):
            if j >= lo:
                return st, j - lo
        raise IndexError(j)

    def _run_shard(self, si: int, limit: int | None) -> None:
        st = self.sts[si]
        if st.done:
            return
        if self.devices is not None:
            import jax

            with jax.default_device(self.devices[si % len(self.devices)]):
                self.packer._block_run(st, limit)
        else:
            self.packer._block_run(st, limit)

    def advance(self, limit: int | None) -> bool:
        live = [i for i, st in enumerate(self.sts) if not st.done]
        if not live:
            return False
        before = [self.sts[i].it for i in live]
        if len(live) == 1:
            self._run_shard(live[0], limit)
        else:
            with ThreadPoolExecutor(max_workers=len(live)) as ex:
                for _ in ex.map(lambda i: self._run_shard(i, limit), live):
                    pass
        return any(self.sts[i].it > b for i, b in zip(live, before))


class _FleetIsland:
    """View of one member problem of a `_SAFleetGroup`."""

    def __init__(self, group: _SAFleetGroup, j: int):
        self.group = group
        self.j = j
        self.packer = group.packer
        self.eliminated = False

    def done(self) -> bool:
        st, j = self.group.state_of(self.j)
        return st.done or self.packer._block_frozen(st, j)

    def extend(self, it_limit: int) -> None:
        st, _ = self.group.state_of(self.j)
        self.packer._block_extend(st, it_limit)

    def eliminate(self) -> None:
        st, j = self.group.state_of(self.j)
        self.packer._block_eliminate(st, j)
        self.eliminated = True

    def raw(self) -> tuple[int, int]:
        st, j = self.group.state_of(self.j)
        cost = int(st.gbest_cost[j])
        if st.hetero:
            ovf = int(st.batch.overflow_rows(
                st.g_UK[j : j + 1], np.asarray([j])
            )[0])
        else:
            ovf = 0
        return cost, ovf

    def best_solution(self) -> Solution:
        st, j = self.group.state_of(self.j)
        return decode_chain_items(
            st.probs[j], st.g_items[j], st.g_counts[j],
            st.g_kinds[j] if st.hetero else None,
        )

    def migrate_in(self, sol: Solution) -> bool:
        st, j = self.group.state_of(self.j)
        return self.packer._block_migrate(st, j, sol)

    def trace(self) -> list:
        st, j = self.group.state_of(self.j)
        return st.traces[j]

    def offset(self, t0: float) -> float:
        st, _ = self.group.state_of(self.j)
        return st.t_start - t0

    def iterations(self) -> int:
        (st, j), c = self.group.state_of(self.j), self.packer.n_chains
        return int(st.steps[j * c : (j + 1) * c].sum())

    def truncated(self) -> bool:
        """True iff the fleet stopped on the wall-clock cap — done, but
        neither frozen (patience) nor out of iteration budget."""
        if self.eliminated:
            return False
        st, _ = self.group.state_of(self.j)
        return st.done and not st.frozen and st.it < self.packer.max_iterations


class _GAGroup:
    """All GA islands, advanced in lockstep with stacked fitness calls.

    ``mesh`` row-shards each stacked fitness call over the ``("prob",)``
    sweep mesh — execution shape only, bit-identical (PR 8)."""

    def __init__(self, pairs, mesh=None):
        self.pairs = pairs  # [(packer, run)] in island order
        self.mesh = mesh

    def advance(self, limit: int | None) -> bool:
        progressed = False
        while lockstep_generation(self.pairs, gen_limit=limit, mesh=self.mesh):
            progressed = True
        return progressed


class _GAIsland:
    def __init__(self, packer: GeneticPacker, run):
        self.packer = packer
        self.run = run
        self.eliminated = False

    def done(self) -> bool:
        # exhausted patience counts as done even before the next lockstep
        # call marks it (mirrors _ScalarIsland: no migrants for converged runs)
        return self.run.done or self.run.stale >= self.packer.patience

    def extend(self, gen_limit: int) -> None:
        self.packer._extend_run(self.run, gen_limit)

    def eliminate(self) -> None:
        self.packer._eliminate_run(self.run)
        self.eliminated = True

    def raw(self) -> tuple[int, int]:
        cost = int(self.run.best_cost)
        ovf = int(self.run.best.inventory_overflow()) if self.run.hetero else 0
        return cost, ovf

    def best_solution(self) -> Solution:
        return self.run.best

    def migrate_in(self, sol: Solution) -> bool:
        return self.packer._migrate_in(self.run, sol)

    def trace(self) -> list:
        return self.run.trace

    def offset(self, t0: float) -> float:
        return self.run.t0 - t0

    def iterations(self) -> int:
        return self.run.gen

    def truncated(self) -> bool:
        return (
            not self.eliminated
            and self.run.done
            and self.run.gen < self.packer.max_generations
            and self.run.stale < self.packer.patience
        )


class _ScalarIsland:
    """A scalar-loop or single-chain SA island (its own resumable state)."""

    def __init__(self, packer: SimulatedAnnealingPacker, st, single: bool):
        self.packer = packer
        self.st = st
        self.single = single
        self.eliminated = False

    def extend(self, it_limit: int) -> None:
        hook = (
            self.packer._single_extend if self.single
            else self.packer._scalar_extend
        )
        hook(self.st, it_limit)

    def eliminate(self) -> None:
        self.packer._loop_eliminate(self.st)
        self.eliminated = True

    def advance(self, limit: int | None) -> bool:
        if self.st.done:
            return False
        before = self.st.it
        run = self.packer._single_run if self.single else self.packer._scalar_run
        run(self.st, limit)
        return self.st.it > before

    def done(self) -> bool:
        return self.st.done or self.st.stale >= self.packer.patience

    def raw(self) -> tuple[int, int]:
        return int(self.st.best_cost), int(self.st.best_ovf)

    def best_solution(self) -> Solution:
        return self.st.best

    def migrate_in(self, sol: Solution) -> bool:
        hook = (
            self.packer._single_migrate if self.single
            else self.packer._scalar_migrate
        )
        return hook(self.st, sol)

    def trace(self) -> list:
        return self.st.trace

    def offset(self, t0: float) -> float:
        return self.st.t_start - t0

    def iterations(self) -> int:
        return self.st.it

    def truncated(self) -> bool:
        return (
            not self.eliminated
            and self.st.done
            and self.st.it < self.packer.max_iterations
            and self.st.stale < self.packer.patience
        )


def _merge_traces(parts: list[tuple[float, list]]) -> list:
    """Global monotone best-so-far trace across (offset, trace) parts."""
    events: list[tuple[float, float]] = []
    for offset, tr in parts:
        events.extend((offset + t, cc) for t, cc in tr)
    events.sort()
    merged: list = []
    best = None
    for t, cc in events:
        if best is None or cc < best:
            best = cc
            merged.append((t, cc))
    return merged


def _sa_fleet_key(packer: SimulatedAnnealingPacker, resolved: str) -> tuple:
    """Engine signature under which sa-s islands share one fleet: everything
    that shapes the array program except the seed (per-island RNG streams
    keep differently-seeded islands independent inside one fleet)."""
    return (
        resolved, packer.n_chains, packer.t0, packer.rc, packer.swap_moves,
        packer.p_adm_w, packer.p_adm_h, packer.intra_layer,
        packer.max_iterations, packer.patience, packer.max_seconds,
        packer.exchange_every, packer.ladder_min, packer.ladder_max,
        packer.p_kind, packer.inventory_penalty,
    )


def _family_stride(family: str, interval: int, ga_islands: int) -> int:
    """Barrier stride (iterations/generations per barrier) of one engine
    family — ``"ga"``, ``"scalar"`` (sa-nfd's sequential repack / the
    legacy backend), or ``"delta"`` (fleet and single-chain sa-s) — on a
    heterogeneous lineup; see `_GA_STRIDE_DIV` above.  ``ga_islands`` (the
    lineup's GA island count) scales the SA strides so the delta-kernel
    engines keep annealing for roughly the wall time one stacked GA
    generation takes, instead of idling at the barrier."""
    if family == "ga":
        return max(1, interval // _GA_STRIDE_DIV)
    mult = max(1, ga_islands)
    if family == "scalar":
        return max(1, interval // _SCALAR_STRIDE_DIV) * mult
    return interval * mult


def _group_stride(group, interval: int, ga_islands: int) -> int:
    """`_family_stride` of one built engine group."""
    if isinstance(group, _GAGroup):
        family = "ga"
    elif isinstance(group, _ScalarIsland) and not group.single:
        family = "scalar"
    else:
        family = "delta"
    return _family_stride(family, interval, ga_islands)


def _island_family(packer, resolved: str) -> str:
    """The `_family_stride` family a packer's island lands in."""
    if isinstance(packer, GeneticPacker):
        return "ga"
    if packer.perturbation == "nfd" or resolved == "legacy":
        return "scalar"
    return "delta"


def _island_work(packer, family: str, stride: int) -> int:
    """Ledger units (chain-annealing-step equivalents, see `_GA_GEN_WORK`)
    one island burns per barrier."""
    if family == "ga":
        return stride * packer.n_pop * _GA_GEN_WORK
    if family == "delta" and packer.n_chains > 1:
        return stride * packer.n_chains
    return stride


def _lineup_work(packers, resolved, interval: int) -> int:
    """Total ledger work the given lineup would consume running every
    island to its configured iteration/generation budget (rounded up to
    whole barriers) — the racing driver's "equal total budget" anchor:
    ``pack_portfolio(auto=True)`` defaults its ledger to the default
    lineup's `_lineup_work`, so auto-tuning never spends more than the
    lineup it replaces."""
    fams = [_island_family(p, r) for p, r in zip(packers, resolved)]
    n_ga = fams.count("ga")
    fleet_keys = {
        _sa_fleet_key(p, r)
        for p, r, f in zip(packers, resolved, fams)
        if f == "delta" and p.n_chains > 1
    }
    # group count mirrors pack_portfolio's construction: one GA lockstep
    # pack, one group per distinct fleet signature, one per scalar island
    n_groups = (
        (1 if n_ga else 0)
        + len(fleet_keys)
        + sum(1 for p, f in zip(packers, fams)
              if f == "scalar" or (f == "delta" and p.n_chains == 1))
    )
    multi = n_groups > 1
    seg = interval if interval > 0 else DEFAULT_MIGRATION_EVERY
    total = 0
    for p, f in zip(packers, fams):
        s = _family_stride(f, seg, n_ga) if (multi and interval > 0) else seg
        budget = p.max_generations if f == "ga" else p.max_iterations
        barriers = -(-int(budget) // s)  # ceil: whole-barrier accounting
        total += barriers * _island_work(p, f, s)
    return total


class _Race:
    """Successive-halving race state over the portfolio's island adapters.

    The ledger (``budget``, in `_island_work` units) is split evenly over
    ``halvings + 1`` phases; each time a phase's share is spent the worse
    half of the surviving islands is eliminated (penalized best cost,
    first island wins ties) until ``final_k`` remain, and the rest of the
    ledger — including everything the eliminated islands never ran — is
    spent advancing the survivors further (docs/DESIGN.md section 16).
    Every decision is a pure function of island trajectories and the
    static work weights, so races are bit-reproducible and the state
    round-trips through the portfolio checkpoint payload."""

    def __init__(self, work: list[int], budget: int, final_k: int):
        self.work = [int(w) for w in work]
        self.budget = int(budget)
        self.final_k = max(1, int(final_k))
        n = len(work)
        self.halvings = 0
        s = n
        while s > self.final_k:
            s = max(self.final_k, (s + 1) // 2)
            self.halvings += 1
        self.phase_budget = max(1, self.budget // (self.halvings + 1))
        self.alive = [True] * n
        self.spent = 0
        self.rung = 0
        self.rung_spent = 0
        self.eliminated: list[dict] = []

    def live(self, adapters) -> list[int]:
        """Islands still racing AND still able to advance (not frozen)."""
        return [
            k for k, isl in enumerate(adapters)
            if self.alive[k] and not isl.done()
        ]

    def charge(self, live: list[int]) -> bool:
        """Burn one barrier's work for ``live``; False when the ledger
        cannot cover it (the race is over — never overspends)."""
        cost = sum(self.work[k] for k in live)
        if cost <= 0 or self.spent + cost > self.budget:
            return False
        self.spent += cost
        self.rung_spent += cost
        return True

    def maybe_halve(self, adapters, barrier: int, lam: float) -> None:
        """At a rung boundary (this phase's ledger share is spent), keep
        the best half of the surviving islands and eliminate the rest."""
        if self.rung >= self.halvings or self.rung_spent < self.phase_budget:
            return
        self.rung += 1
        self.rung_spent = 0
        racing = [k for k in range(len(adapters)) if self.alive[k]]
        keep = max(self.final_k, (len(racing) + 1) // 2)
        if keep >= len(racing):
            return
        vals = {
            k: (lambda c, o: c + lam * o)(*adapters[k].raw()) for k in racing
        }
        ranked = sorted(racing, key=lambda k: (vals[k], k))
        for k in ranked[keep:]:
            self.alive[k] = False
            adapters[k].eliminate()
            self.eliminated.append(
                {"island": k, "barrier": int(barrier), "value": float(vals[k])}
            )

    def state(self) -> dict:
        """JSON-able snapshot payload (checkpoint codec)."""
        return {
            "budget": self.budget,
            "spent": self.spent,
            "rung": self.rung,
            "rung_spent": self.rung_spent,
            "eliminated": self.eliminated,
        }

    def restore(self, state: dict, adapters) -> None:
        """Re-enter a checkpointed race: replay the recorded eliminations
        onto the freshly restored adapters (idempotent — the engine states
        in the snapshot are already frozen/stopped) and resume the ledger."""
        self.spent = int(state["spent"])
        self.rung = int(state["rung"])
        self.rung_spent = int(state["rung_spent"])
        self.eliminated = [dict(e) for e in state["eliminated"]]
        for e in self.eliminated:
            k = int(e["island"])
            self.alive[k] = False
            adapters[k].eliminate()


def _group_label(group, i: int) -> str:
    if isinstance(group, _SAFleetGroup):
        return f"g{i}:fleet"
    if isinstance(group, _GAGroup):
        return f"g{i}:ga"
    return f"g{i}:single" if group.single else f"g{i}:scalar"


def _timed_advance(group, limit) -> tuple[bool, float]:
    """Side-lane unit of work: advance one group to its barrier limit and
    report (progressed, seconds).  Groups share no mutable state and each
    island consumes only its own RNG stream, so running these on a thread
    pool is bit-identical to the serial loop."""
    t = time.perf_counter()
    progressed = group.advance(limit)
    return progressed, time.perf_counter() - t


def _pump(gen, d_e):
    """Feed one delta-cost answer into a `_block_gen` step generator."""
    try:
        return gen.send(d_e)
    except StopIteration:
        return None


def _advance_fused(
    fleet: "_SAFleetGroup", ga: "_GAGroup", fleet_limit, ga_limit
) -> tuple[bool, bool]:
    """Advance the SA fleet and the GA lockstep pack *together*, answering
    one fleet step request and one stacked GA generation's fitness batch
    through a single ``binpack_portfolio_step`` device program whenever
    both have work (odd cycles — fleet drained, GA still running, or a
    multi-population-size lineup — fall back to the separate kernels).

    Bit-parity holds by construction: the fused kernel returns exactly the
    totals/deltas the separate ``binpack_fitness`` / ``binpack_sa_step``
    calls would (exact integer arithmetic, pinned in tests), and each
    engine still consumes only its own RNG stream in its own order.
    Returns (fleet_progressed, ga_progressed)."""
    from repro.kernels.binpack_portfolio_step.ops import portfolio_step

    packer, st = fleet.packer, fleet.sts[0]  # fuse requires one shard
    before = st.it
    gen = None if st.done else packer._block_gen(st, fleet_limit)
    req = next(gen, None) if gen is not None else None
    ga_progressed = False
    while True:
        advanced, batches = lockstep_begin(ga.pairs, ga_limit)
        if req is None and not advanced:
            break
        if req is not None and len(batches) == 1:
            batch = batches[0]
            W, H, Km = stack_geometry([r for _, r, _ in batch])
            old_w, old_h, new_w, new_h, old_k, new_k = req
            totals, d_e = portfolio_step(
                W, H, old_w, old_h, new_w, new_h,
                modes=st.modes0, backend=st.backend, interpret=st.interpret,
                kinds=Km, old_k=old_k, new_k=new_k,
                kind_tables=st.kt if old_k is not None else None,
                mesh=st.mesh,
            )
            lockstep_apply(batch, totals)
            batches = []
            req = _pump(gen, d_e)
        elif req is not None:
            req = _pump(gen, packer._block_eval(st, req))
        for batch in batches:
            lockstep_apply(
                batch,
                stacked_population_costs(
                    [r for _, r, _ in batch], batch[0][1].backend,
                    mesh=ga.mesh,
                ),
            )
        if lockstep_finish(advanced):
            ga_progressed = True
    return st.it > before, ga_progressed


def pack_portfolio(
    prob: PackingProblem,
    islands: Sequence[IslandSpec] | None = None,
    n_islands: int = 4,
    algorithms: Sequence[str] = ("ga-nfd", "sa-s", "sa-nfd"),
    seed: int = 0,
    max_seconds: float = 30.0,
    migration_every: int | None = None,
    intra_layer: bool = False,
    backend: str = "auto",
    max_workers: int | None = None,
    sa_chains: int = 8,
    scheduler: str = "concurrent",
    fused: bool | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    on_checkpoint=None,
    n_shards: int = 1,
    mesh=None,
    auto: bool = False,
    race_grid=None,
    race_budget: int | None = None,
    race_final: int = 2,
    **hyper,
) -> PackingResult:
    """Run K differently-seeded islands as one fleet; return the best result.

    **Self-tuning portfolio (racing).**  ``auto=True`` replaces the fixed
    lineup with a successive-halving hyperparameter race: every config in
    ``race_grid`` (default `DEFAULT_RACE_GRID` — chain counts, temperature
    ladders, population sizes, mutation rates; entries are ``(algorithm,
    hyper-overrides)`` pairs or full `IslandSpec`s, seeded ``seed + k``)
    starts as an island, and at migration barriers the race ledger decides
    who keeps running.  The ledger (``race_budget``, in chain-annealing-step
    equivalents — see `_island_work`) defaults to exactly the total work the
    *default* lineup (``n_islands`` islands cycling ``algorithms``) would
    consume under the same iteration/generation budgets, so auto-tuning
    never spends more than the lineup it replaces.  The ledger is split
    evenly over ``log2(N / race_final) + 1`` phases; at each phase boundary
    the worse half of the surviving islands (penalized best cost, first
    island wins ties) is eliminated — elimination just stops advancing the
    island (a fleet member freezes, a GA run is marked done), so survivors'
    RNG streams are untouched — and the freed budget is *reallocated*: the
    survivors' engine budgets are extended barrier by barrier until the
    ledger is spent.  Races are bit-reproducible, machine-independent, and
    checkpoint/resume-safe like any other portfolio run (the race state
    rides the snapshot payload); ``params["race"]`` records the ledger,
    the eliminations, and the survivors.  Per-island ``max_iterations`` /
    ``max_generations`` only anchor the default ledger — the race itself
    extends survivors past them by design (patience still freezes islands,
    and ``max_seconds`` stays the outer safety cap).
    Racing semantics: docs/DESIGN.md section 16.

    ``islands`` gives full control; otherwise ``n_islands`` specs are derived
    by cycling ``algorithms`` with seeds ``seed, seed+1, ...``.  ``hyper``
    accepts the same Table-2 names as :func:`repro.core.api.pack` and applies
    to every island (per-island ``IslandSpec.hyper`` overrides win).

    ``migration_every`` is an **iteration/generation count** (default 64,
    `DEFAULT_MIGRATION_EVERY`): each barrier advances the delta-kernel SA
    islands that many annealing steps, then broadcasts the global best into
    every other live island's worst warm slot.  On heterogeneous lineups
    each engine family advances at its own per-family stride (GA islands
    ``migration_every // 32`` generations and scalar loops
    ``migration_every // 4`` iterations per barrier, min 1; the
    delta-kernel SA strides scale with the lineup's GA island count — see
    `_group_stride`): strides are static functions of the lineup only, so
    trajectories stay machine-independent, and no family's segment can
    park the barrier (docs/DESIGN.md section 13).  Pass ``migration_every=0`` to disable
    migration (islands run independently to their budgets).
    ``max_seconds`` is an outer safety cap only — for bit-reproducible,
    machine-independent runs give the islands iteration budgets
    (``max_iterations`` / ``max_generations``) and a large ``max_seconds``,
    exactly as with :func:`repro.core.api.pack_sweep`.

    ``scheduler`` picks how groups advance *between* barriers:
    ``"concurrent"`` (default) runs the device-dispatch lane (the SA fleet,
    fused with the GA pack when ``fused`` engages) on the calling thread
    and every other engine group on a `ThreadPoolExecutor` side lane;
    ``"serial"`` is the PR-5 reference loop.  Both schedules are
    **bit-identical** — groups share no mutable state and each island
    consumes only its own RNG stream, so concurrency changes wall-clock,
    never results (pinned in ``tests/test_portfolio_concurrent.py``).
    ``fused=None`` (auto) routes each barrier's SA fleet step requests and
    stacked GA fitness batch through one combined
    ``binpack_portfolio_step`` device program when both engines resolved to
    a jax backend ("ref"/"pallas"); ``True``/``False`` force it.  On a CPU
    host SA auto-resolves to host numpy, so auto keeps fused dispatch off
    there.

    A "sa-s" island runs the batched multi-chain annealer with ``sa_chains``
    temperature-laddered chains; all such islands advance as ONE
    `_anneal_block` fleet (K islands x C chains of problem-major rows), so
    the portfolio's SA work is a single vectorized array program.  A
    single-island portfolio is bit-identical to the standalone
    ``pack(prob, algorithm, seed=...)`` run — same engines, same RNG
    streams, no migration.

    Heterogeneous device scenarios need no extra wiring: build the problem
    with an inventory (``get_problem(name, device="U280")``) and every
    island explores RAM-kind lanes under the shared inventory penalty —
    migrated solutions carry their kind lanes with them, and the ``p_kind``
    / ``inventory_penalty`` hyperparameters pass through like any Table-2
    name.

    ``max_workers`` is deprecated and ignored: the fleet-native portfolio
    has no thread pool (see :func:`pack_portfolio_threads` for the legacy
    engine, kept as a benchmark baseline).

    Scaling past one device (PR 8, docs/DESIGN.md section 14): ``n_shards``
    splits the sa-s island fleet into that many contiguous sub-fleets
    advanced concurrently between barriers, and ``mesh`` (a
    :func:`repro.launch.mesh.make_sweep_mesh` device mesh) row-shards the
    fleet's annealing steps and the GA pack's stacked fitness calls over
    its ``("prob",)`` axis (one shard) or pins the sub-fleets round-robin
    to its devices (several shards).  Both are execution-shape knobs only:
    every shard count and mesh is **bit-identical** to the default
    single-device run, and checkpoints are cut in a canonical merged layout
    so a run may resume at a different shard count (pinned in
    ``tests/test_sharded.py``).  Fused dispatch needs the fleet in one
    piece, so ``n_shards > 1`` disables it.

    Crash safety (docs/DESIGN.md section 12): with ``checkpoint_dir`` the
    run cuts a durable snapshot of every island's engine state (plus the
    barrier/migration counters) every ``checkpoint_every`` migration
    barriers; ``resume=True`` restarts from the newest *intact* snapshot
    and — because barrier segmentation never changes trajectories — lands
    on a result bit-identical to an uninterrupted same-seed run (pinned by
    ``tests/test_resume.py``).  ``max_seconds`` is not part of the
    checkpoint identity, so a preempted run may resume under a fresh wall
    budget.  ``on_checkpoint(step)`` fires after each durable write.

    If the wall-clock cap cuts any island short of its iteration/patience
    budget, the result's ``params["truncated_by_wallclock"]`` is True and a
    ``RuntimeWarning`` is emitted (``params["barriers"]`` records how many
    migration barriers completed) — a truncated portfolio is NOT
    bit-reproducible across machines.

    Wall-clock attribution lands in the result's params:
    ``params["barrier_seconds"]`` is the per-barrier wall time and
    ``params["group_seconds"]`` maps each engine group (``"g0:ga"``,
    ``"g1:fleet"``, ``"g2:scalar"``, ...; a fused pair reports as
    ``"g0+g1:fused"``) to its cumulative advance seconds, so the bench can
    see where a lineup's time goes.  Timing keys are diagnostics only and
    exempt from the bit-reproducibility contract.
    """
    from .api import make_packer  # late import: api imports nothing from here

    if max_workers is not None:
        warnings.warn(
            "pack_portfolio(max_workers=...) is deprecated and ignored: the "
            "portfolio is fleet-native (no thread pool); use "
            "pack_portfolio_threads for the legacy engine",
            DeprecationWarning,
            stacklevel=2,
        )
    if not auto and (race_grid is not None or race_budget is not None):
        raise ValueError("race_grid/race_budget require auto=True")
    if n_islands < 1:
        raise ValueError("n_islands must be >= 1")
    default_specs = [
        IslandSpec(algorithm=algorithms[k % len(algorithms)], seed=seed + k)
        for k in range(n_islands)
    ]
    if auto:
        if islands is not None:
            raise ValueError(
                "pass auto=True (with race_grid=...) or islands=..., not both"
            )
        grid = DEFAULT_RACE_GRID if race_grid is None else list(race_grid)
        islands = [
            entry if isinstance(entry, IslandSpec)
            else IslandSpec(algorithm=entry[0], seed=seed + k,
                            hyper=dict(entry[1]))
            for k, entry in enumerate(grid)
        ]
    elif islands is None:
        islands = default_specs
    islands = list(islands)
    if not islands:
        raise ValueError("portfolio needs at least one island")
    interval = (
        DEFAULT_MIGRATION_EVERY if migration_every is None
        else int(migration_every)
    )
    ck = None
    if checkpoint_dir is not None:
        from .resume import PortfolioCheckpointer, portfolio_config_key

        ck = PortfolioCheckpointer(
            checkpoint_dir,
            portfolio_config_key(
                prob, islands, interval, intra_layer, backend, sa_chains,
                hyper,
                race=(
                    (int(race_budget) if race_budget is not None else None,
                     int(race_final))
                    if auto else None
                ),
            ),
            every=checkpoint_every, resume=resume, on_checkpoint=on_checkpoint,
        )
    hetero = prob.n_kinds > 1
    t0 = time.perf_counter()

    # --- build islands; group sa-s fleets, GA lockstep pairs, scalar loops
    packers = [
        make_packer(
            spec.algorithm,
            seed=spec.seed,
            max_seconds=max_seconds,
            intra_layer=intra_layer,
            backend=backend,
            **{
                **({"n_chains": sa_chains} if spec.algorithm == "sa-s" else {}),
                **hyper,
                **spec.hyper,
            },
        )
        for spec in islands
    ]
    # cross-island ranking weight for the global best: the portfolio-level
    # override if given, else the strictest island's penalty (per-island
    # IslandSpec.hyper overrides may differ; ranking under the max keeps a
    # feasible packing outranking an overflowing one for every island)
    lam = (
        float(hyper["inventory_penalty"])
        if "inventory_penalty" in hyper
        else max(float(p.inventory_penalty) for p in packers)
    )
    adapters: list = [None] * len(islands)
    groups: list = []
    ga_pairs: list = []
    fleet_members: dict[tuple, list] = {}  # fleet key -> [(k, packer)]
    for k, packer in enumerate(packers):
        if isinstance(packer, GeneticPacker):
            b = packer._resolve_backend()
            run = packer._start_run(
                prob, np.random.default_rng(packer.seed), None, b
            )
            totals = (
                packer._batched_costs(
                    run.W, run.H, b, run.Km, run.kt, run.modes0, mesh=mesh
                )
                if run.batched
                else None
            )
            packer._eval_init(run, totals)
            ga_pairs.append((packer, run))
            adapters[k] = _GAIsland(packer, run)
            continue
        resolved = packer._resolve_backend()
        packer._hetero = hetero
        if packer.perturbation == "nfd" or resolved == "legacy":
            st = packer._scalar_start(prob, None)
            isl = _ScalarIsland(packer, st, single=False)
            groups.append(isl)
            adapters[k] = isl
        elif packer.n_chains == 1:
            st = packer._single_start(prob, None, resolved)
            isl = _ScalarIsland(packer, st, single=True)
            groups.append(isl)
            adapters[k] = isl
        else:
            fleet_members.setdefault(_sa_fleet_key(packer, resolved), []).append(
                (k, packer)
            )
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if ga_pairs:
        groups.append(_GAGroup(ga_pairs, mesh=mesh))
    for members in fleet_members.values():
        fleet = _SAFleetGroup(
            members[0][1],
            prob,
            [np.random.default_rng(p.seed) for _, p in members],
            members[0][1]._resolve_backend(),
            n_shards=n_shards,
            mesh=mesh,
        )
        groups.append(fleet)
        for j, (k, _) in enumerate(members):
            adapters[k] = _FleetIsland(fleet, j)

    # --- barriered fleet loop: advance everything, then migrate
    if scheduler not in ("concurrent", "serial"):
        raise ValueError(
            f"unknown scheduler {scheduler!r}; options: concurrent, serial"
        )
    barrier = 0
    migrations = 0
    truncated = False
    single = len(adapters) == 1
    if ck is not None:
        restored = ck.restore_groups(groups)
        if restored is not None:
            barrier, migrations = restored
    # with checkpointing, runs that would otherwise advance in one
    # unbounded call (single island, or migration disabled) still pause at
    # DEFAULT_MIGRATION_EVERY-iteration barriers purely to cut snapshots —
    # barrier segmentation never changes trajectories (PR-5 contract)
    seg = interval if interval > 0 else (
        DEFAULT_MIGRATION_EVERY if (ck is not None or auto) else 0
    )
    # per-family strides rebalance heterogeneous lineups (see the module
    # constants); homogeneous lineups and snapshot-only segmentation keep
    # the uniform stride.  Strides are deterministic functions of the
    # lineup and ``migration_every``, so they are part of the trajectory
    # contract; ``scheduler``/``fused`` are not (dispatch only).
    multi = len(groups) > 1
    n_ga_islands = len(ga_pairs)
    strides = [
        _group_stride(g, seg, n_ga_islands) if (multi and interval > 0)
        else seg
        for g in groups
    ]
    labels = [_group_label(g, i) for i, g in enumerate(groups)]
    # --- racing state: static work weights, the ledger, and (on resume)
    # the replayed eliminations
    race = None
    agroup: list[int] = []
    members_of: list[list[int]] = [[] for _ in groups]
    if auto:
        gi_of = {id(g): i for i, g in enumerate(groups)}
        ga_gi = next(
            (i for i, g in enumerate(groups) if isinstance(g, _GAGroup)), None
        )
        work: list[int] = []
        for k, isl in enumerate(adapters):
            if isinstance(isl, _FleetIsland):
                g, fam = gi_of[id(isl.group)], "delta"
            elif isinstance(isl, _GAIsland):
                g, fam = ga_gi, "ga"
            else:
                g = gi_of[id(isl)]
                fam = "scalar" if not isl.single else "delta"
            agroup.append(g)
            members_of[g].append(k)
            work.append(_island_work(isl.packer, fam, strides[g]))
        if race_budget is None:
            # equal total budget vs the lineup auto replaces: the default
            # ``n_islands`` lineup's work under the same budget knobs
            dpackers = [
                make_packer(
                    spec.algorithm, seed=spec.seed, max_seconds=max_seconds,
                    intra_layer=intra_layer, backend=backend,
                    **{
                        **({"n_chains": sa_chains}
                           if spec.algorithm == "sa-s" else {}),
                        **hyper,
                    },
                )
                for spec in default_specs
            ]
            race_budget = _lineup_work(
                dpackers, [p._resolve_backend() for p in dpackers], interval
            )
        race = _Race(work, race_budget, race_final)
        if ck is not None and ck.race is not None:
            race.restore(ck.race, adapters)
    # the fused pair: the (only) SA fleet group + the GA lockstep pack,
    # merged into one main-thread dispatch unit when both engines resolved
    # to a jax backend (forced either way via ``fused``)
    fi = next(
        (i for i, g in enumerate(groups) if isinstance(g, _SAFleetGroup)), None
    )
    gi = next(
        (i for i, g in enumerate(groups) if isinstance(g, _GAGroup)), None
    )
    fuse = (
        scheduler == "concurrent" and fi is not None and gi is not None
        and sum(isinstance(g, _SAFleetGroup) for g in groups) == 1
        and len(groups[fi].sts) == 1  # fused dispatch needs one fleet shard
        and (
            fused if fused is not None
            else (
                groups[fi].sts[0].backend in ("ref", "pallas")
                and all(r.backend in ("ref", "pallas") and r.batched
                        for _, r in groups[gi].pairs)
            )
        )
    )
    # main-thread lane: the fused pair, else the SA fleet (device dispatch
    # window), else the first group; everything else rides the side lane
    main_idx = {fi, gi} if fuse else {fi if fi is not None else 0}
    side_idx = [i for i in range(len(groups)) if i not in main_idx]
    pool = (
        ThreadPoolExecutor(max_workers=len(side_idx))
        if scheduler == "concurrent" and side_idx
        else None
    )
    group_seconds: dict[str, float] = {lab: 0.0 for lab in labels}
    if fuse:
        fused_label = f"g{min(fi, gi)}+g{max(fi, gi)}:fused"
        group_seconds[fused_label] = 0.0
        for i in sorted(main_idx):
            group_seconds.pop(labels[i])
    barrier_seconds: list[float] = []
    try:
        # racing gates the loop itself: a budget-done survivor is revived by
        # the extension below, so only the race's live/ledger checks (or the
        # wall cap) may end an auto run
        while race is not None or any(not isl.done() for isl in adapters):
            if barrier > 0 and time.perf_counter() - t0 > max_seconds:
                truncated = True
                break
            t_bar = time.perf_counter()
            unbounded = race is None and ((single and ck is None) or seg <= 0)
            limits = [
                None if unbounded else (barrier + 1) * s for s in strides
            ]
            idle: frozenset = frozenset()
            if race is not None:
                # extend every surviving island's engine budget to this
                # barrier's limit FIRST (reallocation is just a larger
                # it_limit — it revives islands that stopped on budget,
                # funded by the work the eliminated islands never ran),
                # then let the ledger gate the barrier
                for k, isl in enumerate(adapters):
                    if race.alive[k]:
                        isl.extend(limits[agroup[k]])
                live = race.live(adapters)
                if not live:
                    break  # every survivor frozen or wall-capped
                if not race.charge(live):
                    break  # ledger spent: the race is over
                # eliminated islands vacate their lane: a group with no
                # live member is never dispatched (its states are inert, so
                # skipping it cannot perturb survivors' RNG streams)
                idle = frozenset(
                    i for i, members in enumerate(members_of)
                    if all(adapters[k].done() for k in members)
                )
            barrier += 1
            progressed = [False] * len(groups)
            if pool is not None:
                futures = {
                    i: pool.submit(_timed_advance, groups[i], limits[i])
                    for i in side_idx
                    if i not in idle
                }
            else:
                futures = {}
            t_main = time.perf_counter()
            if fuse:
                progressed[fi], progressed[gi] = _advance_fused(
                    groups[fi], groups[gi], limits[fi], limits[gi]
                )
                group_seconds[fused_label] += time.perf_counter() - t_main
            else:
                mains = sorted(main_idx) if pool is not None else [
                    i for i in range(len(groups)) if i not in futures
                ]
                for i in mains:
                    if i in idle:
                        continue
                    progressed[i], dt = _timed_advance(groups[i], limits[i])
                    group_seconds[labels[i]] += dt
            for i, fut in futures.items():
                progressed[i], dt = fut.result()
                group_seconds[labels[i]] += dt
            if not single and interval > 0:
                # deterministic migration: strict-min global best (first
                # island wins ties) lands in every OTHER live island's
                # worst warm slot
                vals = [
                    c + lam * o for c, o in (isl.raw() for isl in adapters)
                ]
                src = min(range(len(vals)), key=vals.__getitem__)
                migrant = adapters[src].best_solution()
                for k, isl in enumerate(adapters):
                    if k != src:
                        migrations += isl.migrate_in(migrant)
            if race is not None:
                race.maybe_halve(adapters, barrier, lam)
            if ck is not None and barrier % ck.every == 0:
                ck.save_groups(
                    groups, barrier, migrations,
                    race=race.state() if race is not None else None,
                )
            barrier_seconds.append(time.perf_counter() - t_bar)
            if not any(progressed):
                break  # no island can move: budgets exhausted mid-barrier
    finally:
        if pool is not None:
            pool.shutdown()

    # --- assemble the portfolio result (strict-min, first island wins ties)
    wall = time.perf_counter() - t0
    # the outer cap above, or any island's own engine hitting its wall cap
    # short of its iteration/patience budget, silently breaks seed-level
    # reproducibility — surface it instead (satellite of DESIGN.md sec. 12)
    truncated = truncated or any(isl.truncated() for isl in adapters)
    if truncated:
        warnings.warn(
            f"pack_portfolio stopped on wall-clock after {barrier} "
            "barrier(s) before the islands' iteration/patience budgets; the "
            "result is NOT seed-reproducible (params['truncated_by_wallclock']"
            " is True). Give islands iteration budgets for reproducible runs.",
            TruncationWarning,
            stacklevel=2,
        )
    raws = [isl.raw() for isl in adapters]
    vals = [c + lam * o for c, o in raws]
    best_k = min(range(len(vals)), key=vals.__getitem__)
    best_sol = adapters[best_k].best_solution()
    best_cost = raws[best_k][0]
    trace = _merge_traces([(isl.offset(t0), isl.trace()) for isl in adapters])
    trace.append((wall, vals[best_k] if hetero else best_cost))
    names = "+".join(p.name for p in packers)
    return PackingResult(
        solution=best_sol,
        cost=int(best_cost),
        efficiency=best_sol.efficiency(),
        wall_time_s=wall,
        algorithm=f"portfolio[{names}]" + ("-intra" if intra_layer else ""),
        trace=trace,
        iterations=sum(isl.iterations() for isl in adapters),
        params=dict(
            islands=[
                dict(algorithm=s.algorithm, seed=s.seed, **s.hyper) for s in islands
            ],
            barriers=barrier,
            migration_every=interval,
            migrations=migrations,
            truncated_by_wallclock=truncated,
            backend=backend,
            seed=seed,
            scheduler=scheduler,
            n_shards=n_shards,
            fused=bool(fuse),
            strides=dict(zip(labels, strides)),
            barrier_seconds=barrier_seconds,
            group_seconds=group_seconds,
            **(
                dict(race=dict(
                    budget=race.budget,
                    spent=race.spent,
                    halvings=race.halvings,
                    phase_budget=race.phase_budget,
                    final_k=race.final_k,
                    work=list(race.work),
                    survivors=[
                        k for k, a in enumerate(race.alive) if a
                    ],
                    eliminated=race.eliminated,
                ))
                if race is not None else {}
            ),
        ),
    )


# ---------------------------------------------------- legacy thread portfolio
class _Island:
    """A packer plus its warm state, advanced one budgeted round at a time
    (the legacy thread-pool portfolio's unit of work)."""

    def __init__(self, prob: PackingProblem, spec: IslandSpec, packer):
        self.prob = prob
        self.spec = spec
        self.packer = packer
        self.is_ga = isinstance(packer, GeneticPacker)
        self.pop: list[Solution] | None = None  # GA warm population
        self.chains: list[Solution] | None = None  # SA warm incumbents (1/chain)

    def run_round(self, budget_s: float, round_idx: int) -> PackingResult:
        self.packer.max_seconds = budget_s
        self.packer.seed = self.spec.seed + _ROUND_SEED_STRIDE * round_idx
        if self.is_ga:
            result = self.packer.pack(self.prob, init_pop=self.pop)
            self.pop = self.packer.last_population_
        else:
            result = self.packer.pack(self.prob, init=self.chains)
            self.chains = self.packer.last_chains_
        return result

    def migrate_in(self, best: Solution, best_val: float, score) -> None:
        """The global best replaces this island's worst warm individual/chain
        (``score`` is the inventory-penalized cost on heterogeneous problems,
        the plain cost otherwise)."""
        warm = self.pop if self.is_ga else self.chains
        if not warm:
            return
        worst = max(range(len(warm)), key=lambda i: score(warm[i]))
        if score(warm[worst]) > best_val:
            warm[worst] = best.copy()


def pack_portfolio_threads(
    prob: PackingProblem,
    islands: Sequence[IslandSpec] | None = None,
    n_islands: int = 4,
    algorithms: Sequence[str] = ("ga-nfd", "sa-s", "sa-nfd"),
    seed: int = 0,
    max_seconds: float = 30.0,
    migration_every: float | None = None,
    intra_layer: bool = False,
    backend: str = "auto",
    max_workers: int | None = None,
    sa_chains: int = 8,
    **hyper,
) -> PackingResult:
    """The legacy thread-pool portfolio, kept as the benchmark baseline.

    K islands evolve concurrently on a thread pool under one shared
    wall-clock budget, synchronizing every ``migration_every`` *seconds*
    (default ``max_seconds / 4``) to migrate the global best.  Rounds are
    wall-clock budgeted, so results vary with machine speed and load —
    exactly the nondeterminism the fleet-native :func:`pack_portfolio`
    replaced (``benchmarks/run.py --only portfolio`` compares the two).

    **Baseline only.**  This engine is kept solely as the comparison point
    for the bench lineup matrix and ``tools/portfolio_gate.py``; it is
    outside the determinism, checkpoint/resume, and scheduler contracts
    and intentionally grows no ``scheduler``/``fused``/``checkpoint_dir``
    surface (pinned by ``tests/test_portfolio_concurrent.py``).  Use
    :func:`pack_portfolio` for real runs.
    """
    from .api import make_packer  # late import: api imports nothing from here

    if islands is None:
        if n_islands < 1:
            raise ValueError("n_islands must be >= 1")
        islands = [
            IslandSpec(algorithm=algorithms[k % len(algorithms)], seed=seed + k)
            for k in range(n_islands)
        ]
    if not islands:
        raise ValueError("portfolio needs at least one island")
    pool = [
        _Island(
            prob,
            spec,
            make_packer(
                spec.algorithm,
                seed=spec.seed,
                max_seconds=max_seconds,
                intra_layer=intra_layer,
                backend=backend,
                **{
                    **({"n_chains": sa_chains} if spec.algorithm == "sa-s" else {}),
                    **hyper,
                    **spec.hyper,
                },
            ),
        )
        for spec in islands
    ]
    interval = migration_every if migration_every is not None else max_seconds / 4.0
    interval = max(interval, 1e-3)

    # island comparisons use the inventory-penalized cost on heterogeneous
    # problems so a feasible packing always outranks an overflowing one
    hetero = prob.n_kinds > 1
    lam = hyper.get("inventory_penalty", DEFAULT_INVENTORY_PENALTY)
    if hetero:
        def score(sol: Solution) -> float:
            return sol.cost() + lam * sol.inventory_overflow()
    else:
        def score(sol: Solution) -> float:
            return sol.cost()

    t0 = time.perf_counter()
    rounds: list[tuple[float, list[PackingResult]]] = []
    best_sol: Solution | None = None
    best_cost = 0
    best_val = 0.0
    iterations = 0
    round_idx = 0
    with ThreadPoolExecutor(max_workers=max_workers or len(pool)) as ex:
        while True:
            elapsed = time.perf_counter() - t0
            remaining = max_seconds - elapsed
            if round_idx > 0 and remaining <= 1e-3:
                break
            budget = min(interval, max(remaining, 1e-3))
            futures = [
                ex.submit(isl.run_round, budget, round_idx) for isl in pool
            ]
            results = [f.result() for f in futures]
            rounds.append((elapsed, results))
            for r in results:
                iterations += r.iterations
                val = score(r.solution)
                if best_sol is None or val < best_val:
                    best_sol, best_cost, best_val = r.solution, r.cost, val
            for isl in pool:
                isl.migrate_in(best_sol, best_val, score)
            round_idx += 1
    wall = time.perf_counter() - t0
    trace = _merge_traces(
        [(offset, r.trace) for offset, results in rounds for r in results]
    )
    trace.append((wall, best_cost))
    names = "+".join(isl.packer.name for isl in pool)
    return PackingResult(
        solution=best_sol,
        cost=int(best_cost),
        efficiency=best_sol.efficiency(),
        wall_time_s=wall,
        algorithm=f"portfolio-threads[{names}]" + ("-intra" if intra_layer else ""),
        trace=trace,
        iterations=iterations,
        params=dict(
            islands=[
                dict(algorithm=s.algorithm, seed=s.seed, **s.hyper) for s in islands
            ],
            rounds=round_idx,
            migration_every=interval,
            backend=backend,
            seed=seed,
        ),
    )
