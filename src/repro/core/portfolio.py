"""Multi-seed island portfolio: concurrent GA/SA runs with migration.

The paper's hybrid mappers are stochastic — different seeds land on
different local optima.  A *portfolio* run hedges that variance: K islands
(differently-seeded GA/SA instances, possibly with different algorithms or
hyperparameters) evolve concurrently on a thread pool under one shared
wall-clock budget.  Every ``migration_every`` seconds the islands
synchronize and the global best solution migrates into each island's warm
state (replacing the worst GA individual / the SA incumbent if better), so
good building blocks spread without collapsing diversity between barriers.

The numpy/JAX work inside each island releases the GIL for the batched
evaluation path; the pure-Python mutation loops time-slice.  Thread
scheduling adds no nondeterminism of its own — migration happens at
full-round barriers and each island's RNG stream depends only on its own
seed and the round index — but rounds are wall-clock budgeted, so (as with
any single time-budgeted GA/SA run) results still vary with machine speed
and load.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from .ga import GeneticPacker
from .problem import PackingProblem, PackingResult, Solution
from .sa import SimulatedAnnealingPacker

# offset between per-round reseeds; any large odd constant keeps island
# streams disjoint from the user-visible base seeds
_ROUND_SEED_STRIDE = 7919


@dataclasses.dataclass(frozen=True)
class IslandSpec:
    """One island: which packer, which base seed, which overrides."""

    algorithm: str = "ga-nfd"
    seed: int = 0
    hyper: dict = dataclasses.field(default_factory=dict)


class _Island:
    """A packer plus its warm state, advanced one budgeted round at a time."""

    def __init__(self, prob: PackingProblem, spec: IslandSpec, packer):
        self.prob = prob
        self.spec = spec
        self.packer = packer
        self.is_ga = isinstance(packer, GeneticPacker)
        self.pop: list[Solution] | None = None  # GA warm population
        self.chains: list[Solution] | None = None  # SA warm incumbents (1/chain)

    def run_round(self, budget_s: float, round_idx: int) -> PackingResult:
        self.packer.max_seconds = budget_s
        self.packer.seed = self.spec.seed + _ROUND_SEED_STRIDE * round_idx
        if self.is_ga:
            result = self.packer.pack(self.prob, init_pop=self.pop)
            self.pop = self.packer.last_population_
        else:
            result = self.packer.pack(self.prob, init=self.chains)
            self.chains = self.packer.last_chains_
        return result

    def migrate_in(self, best: Solution, best_val: float, score) -> None:
        """The global best replaces this island's worst warm individual/chain
        (``score`` is the inventory-penalized cost on heterogeneous problems,
        the plain cost otherwise)."""
        warm = self.pop if self.is_ga else self.chains
        if not warm:
            return
        worst = max(range(len(warm)), key=lambda i: score(warm[i]))
        if score(warm[worst]) > best_val:
            warm[worst] = best.copy()


def _merge_traces(rounds: list[tuple[float, list[PackingResult]]]) -> list:
    """Global monotone best-so-far trace across islands and rounds."""
    events: list[tuple[float, int]] = []
    for offset, results in rounds:
        for r in results:
            events.extend((offset + t, c) for t, c in r.trace)
    events.sort()
    merged: list[tuple[float, int]] = []
    best = None
    for t, c in events:
        if best is None or c < best:
            best = c
            merged.append((t, c))
    return merged


def pack_portfolio(
    prob: PackingProblem,
    islands: Sequence[IslandSpec] | None = None,
    n_islands: int = 4,
    algorithms: Sequence[str] = ("ga-nfd", "sa-s", "sa-nfd"),
    seed: int = 0,
    max_seconds: float = 30.0,
    migration_every: float | None = None,
    intra_layer: bool = False,
    backend: str = "auto",
    max_workers: int | None = None,
    sa_chains: int = 8,
    **hyper,
) -> PackingResult:
    """Run K differently-seeded islands concurrently; return the best result.

    ``islands`` gives full control; otherwise ``n_islands`` specs are derived
    by cycling ``algorithms`` with seeds ``seed, seed+1, ...``.  ``hyper``
    accepts the same Table-2 names as :func:`repro.core.api.pack` and applies
    to every island (per-island ``IslandSpec.hyper`` overrides win).

    A "sa-s" island runs the batched multi-chain annealer with ``sa_chains``
    temperature-laddered chains sharing one fused delta-cost evaluation —
    one such island replaces what used to take K scalar SA islands (and
    their K thread slots); its chains warm-restart and receive migrants
    like any other island's population.

    Heterogeneous device scenarios need no extra wiring: build the problem
    with an inventory (``get_problem(name, device="U280")``) and every
    island explores RAM-kind lanes under the shared inventory penalty —
    migrated solutions carry their kind lanes with them, and the ``p_kind``
    / ``inventory_penalty`` hyperparameters pass through like any Table-2
    name.
    """
    from .api import make_packer  # late import: api imports nothing from here

    if islands is None:
        if n_islands < 1:
            raise ValueError("n_islands must be >= 1")
        islands = [
            IslandSpec(algorithm=algorithms[k % len(algorithms)], seed=seed + k)
            for k in range(n_islands)
        ]
    if not islands:
        raise ValueError("portfolio needs at least one island")
    pool = [
        _Island(
            prob,
            spec,
            make_packer(
                spec.algorithm,
                seed=spec.seed,
                max_seconds=max_seconds,
                intra_layer=intra_layer,
                backend=backend,
                **{
                    **({"n_chains": sa_chains} if spec.algorithm == "sa-s" else {}),
                    **hyper,
                    **spec.hyper,
                },
            ),
        )
        for spec in islands
    ]
    interval = migration_every if migration_every is not None else max_seconds / 4.0
    interval = max(interval, 1e-3)

    # island comparisons use the inventory-penalized cost on heterogeneous
    # problems so a feasible packing always outranks an overflowing one
    hetero = prob.n_kinds > 1
    lam = hyper.get("inventory_penalty", 32.0)
    if hetero:
        def score(sol: Solution) -> float:
            return sol.cost() + lam * sol.inventory_overflow()
    else:
        def score(sol: Solution) -> float:
            return sol.cost()

    t0 = time.perf_counter()
    rounds: list[tuple[float, list[PackingResult]]] = []
    best_sol: Solution | None = None
    best_cost = 0
    best_val = 0.0
    iterations = 0
    round_idx = 0
    with ThreadPoolExecutor(max_workers=max_workers or len(pool)) as ex:
        while True:
            elapsed = time.perf_counter() - t0
            remaining = max_seconds - elapsed
            if round_idx > 0 and remaining <= 1e-3:
                break
            budget = min(interval, max(remaining, 1e-3))
            futures = [
                ex.submit(isl.run_round, budget, round_idx) for isl in pool
            ]
            results = [f.result() for f in futures]
            rounds.append((elapsed, results))
            for r in results:
                iterations += r.iterations
                val = score(r.solution)
                if best_sol is None or val < best_val:
                    best_sol, best_cost, best_val = r.solution, r.cost, val
            for isl in pool:
                isl.migrate_in(best_sol, best_val, score)
            round_idx += 1
    wall = time.perf_counter() - t0
    trace = _merge_traces(rounds)
    trace.append((wall, best_cost))
    names = "+".join(isl.packer.name for isl in pool)
    return PackingResult(
        solution=best_sol,
        cost=int(best_cost),
        efficiency=best_sol.efficiency(),
        wall_time_s=wall,
        algorithm=f"portfolio[{names}]" + ("-intra" if intra_layer else ""),
        trace=trace,
        iterations=iterations,
        params=dict(
            islands=[
                dict(algorithm=s.algorithm, seed=s.seed, **s.hyper) for s in islands
            ],
            rounds=round_idx,
            migration_every=interval,
            backend=backend,
            seed=seed,
        ),
    )
