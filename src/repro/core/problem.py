"""Core data model for the CNN-parameter-memory -> FPGA-OCM bin packing problem.

Faithful to Kroes et al., "Evolutionary Bin Packing for Memory-Efficient
Dataflow Inference Acceleration on FPGA" (2020):

* A *buffer* is one CNN parameter memory with a fixed word width (bits) and
  depth (words).  In FINN-style accelerators a layer with parallelism
  ``N_PE x (N_SIMD, D, W)`` contributes ``N_PE`` buffers of width
  ``N_SIMD * W`` bits and depth ``D``.
* A *bin* is a group of buffers co-located in one composed block-RAM
  structure.  Buffers in a bin are stacked in depth; the bin's width is the
  maximum buffer width and its height the sum of buffer depths.  A bin may
  hold at most ``max_items`` buffers (the paper's cardinality constraint,
  derived from the 2 physical BRAM ports; the paper evaluates with 4).
* A RAM primitive (:class:`RAMKind`) supports aspect-ratio modes; a
  (width x height) bin is implemented by tiling primitives in one mode and
  its implementation cost is

      cost(w, h) = min_m ceil(w / w_m) * ceil(h / d_m)

  and the paper's Eq. 1 mapping efficiency generalizes to

      E = stored_bits / (cost * CAPACITY_BITS).

Heterogeneous on-chip memory (PR 3, following the authors' sequel
arXiv:2011.07317): real devices expose several primitive kinds — BRAM18,
BRAM36, URAM288 (fixed 72x4096 aspect), distributed LUTRAM — in fixed
per-device quantities.  An :class:`OCMInventory` lists the available kinds
and counts; every bin of a :class:`Solution` then carries a *RAM-kind lane*
selecting which primitive implements it.  Costs of different kinds are made
commensurable by expressing them in a shared *cost unit* (the gcd of the
kind capacities, so one BRAM18 = 1 unit and one URAM288 = 16 units on a
BRAM18+URAM288 device), and inventory feasibility is a soft constraint:
:meth:`Solution.inventory_overflow` measures the unit-weighted excess over
the per-kind counts, which the engines fold into fitness / acceptance.

The default single-kind BRAM18 problem (no ``ocm``) is bit-identical to the
homogeneous model of the paper — unit weight 1, kind lane all zeros, no
extra RNG draws anywhere.  `tests/test_core_problem.py` pins it against
every published baseline efficiency in the paper's Table 4; see
docs/DESIGN.md section 3 for the heterogeneous extension.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from functools import reduce
from typing import Iterable, Sequence

import numpy as np

# Xilinx BRAM18: 16K data bits + 2K parity bits.  Parity bits are usable as
# data only for aspect widths >= 9, hence the capacity difference per mode.
BRAM18_MODES: tuple[tuple[int, int], ...] = (
    (1, 16384),
    (2, 8192),
    (4, 4096),
    (9, 2048),
    (18, 1024),
    (36, 512),
)
BRAM18_CAPACITY_BITS = 18 * 1024  # Eq. 1 denominator (18432), as in the paper

# Default weight of one unit of inventory overflow in the engines' penalized
# cost (heterogeneous OCM problems; see Solution.inventory_overflow).  The
# single source of truth — api/ga/sa/portfolio all import it, so the GA, the
# SA engines, and the portfolio's migration scoring can never drift apart.
DEFAULT_INVENTORY_PENALTY = 32.0


@dataclasses.dataclass(frozen=True)
class BRAMSpec:
    """A physical RAM primitive with configurable aspect-ratio modes.

    Retained as the single-kind interface (`PackingProblem(bram=...)`);
    heterogeneous problems use :class:`RAMKind` + :class:`OCMInventory`.
    """

    modes: tuple[tuple[int, int], ...] = BRAM18_MODES
    capacity_bits: int = BRAM18_CAPACITY_BITS

    @property
    def mode_widths(self) -> np.ndarray:
        return np.asarray([m[0] for m in self.modes], dtype=np.int64)

    @property
    def mode_depths(self) -> np.ndarray:
        return np.asarray([m[1] for m in self.modes], dtype=np.int64)


# ------------------------------------------------------------- RAM kinds
@dataclasses.dataclass(frozen=True)
class RAMKind:
    """One physical RAM primitive family (aspect modes + capacity)."""

    name: str
    modes: tuple[tuple[int, int], ...]
    capacity_bits: int


# Xilinx 7-series/UltraScale primitives.  BRAM36 is two cascaded BRAM18s
# (parity usable from width 9 -> 36K only at widths >= 9; we model the
# standard data aspects plus the x72 SDP mode).  URAM288 has a single fixed
# 72x4096 aspect.  LUTRAM64 models SLICEM distributed RAM at 64 bits.
BRAM18 = RAMKind("BRAM18", BRAM18_MODES, BRAM18_CAPACITY_BITS)
BRAM36_MODES: tuple[tuple[int, int], ...] = (
    (1, 32768),
    (2, 16384),
    (4, 8192),
    (9, 4096),
    (18, 2048),
    (36, 1024),
    (72, 512),
)
BRAM36 = RAMKind("BRAM36", BRAM36_MODES, 36 * 1024)
URAM288 = RAMKind("URAM288", ((72, 4096),), 288 * 1024)
LUTRAM64 = RAMKind("LUTRAM64", ((1, 64), (2, 32), (4, 16)), 64)

RAM_KINDS: dict[str, RAMKind] = {
    k.name: k for k in (BRAM18, BRAM36, URAM288, LUTRAM64)
}


def register_ram_kind(kind: RAMKind) -> RAMKind:
    """Add a custom primitive to the registry (returns it for chaining)."""
    if not kind.modes or kind.capacity_bits <= 0:
        raise ValueError(f"RAMKind {kind.name!r} needs modes and capacity")
    RAM_KINDS[kind.name] = kind
    return kind


@dataclasses.dataclass(frozen=True)
class OCMInventory:
    """Per-device on-chip-memory inventory: RAM kinds + primitive counts.

    ``counts[k] < 0`` means unbounded (no inventory pressure for that kind).
    Costs across kinds are expressed in a shared integer *cost unit* — the
    gcd of the kind capacities — so kind costs stay exactly comparable:
    ``weights[k] = capacity_bits[k] // unit_bits`` primitives-to-units.
    """

    kinds: tuple[RAMKind, ...]
    counts: tuple[int, ...]
    name: str = ""

    def __post_init__(self):
        if not self.kinds:
            raise ValueError("OCMInventory needs at least one RAM kind")
        if len(self.kinds) != len(self.counts):
            raise ValueError("kinds and counts must have equal length")
        if len({k.name for k in self.kinds}) != len(self.kinds):
            raise ValueError("duplicate RAM kind in inventory")

    @classmethod
    def from_counts(cls, name: str = "", **counts: int) -> "OCMInventory":
        """Build from registry names, e.g. ``from_counts("ZU7EV", BRAM18=624,
        URAM288=96)``.  Keyword order fixes the kind-lane indices (kind 0
        first)."""
        kinds = tuple(RAM_KINDS[n] for n in counts)
        return cls(kinds=kinds, counts=tuple(counts.values()), name=name)

    @property
    def unit_bits(self) -> int:
        return reduce(math.gcd, (k.capacity_bits for k in self.kinds))

    @property
    def weights(self) -> tuple[int, ...]:
        u = self.unit_bits
        return tuple(k.capacity_bits // u for k in self.kinds)

    def kind_index(self, name: str) -> int:
        for i, k in enumerate(self.kinds):
            if k.name == name:
                return i
        raise KeyError(f"no RAM kind {name!r} in inventory {self.name!r}")

    def capacity_units(self) -> int | None:
        """Total bounded capacity in cost units (None if any kind unbounded)."""
        if any(c < 0 for c in self.counts):
            return None
        return sum(c * w for c, w in zip(self.counts, self.weights))


@dataclasses.dataclass(frozen=True)
class Buffer:
    """One logical parameter memory."""

    width: int  # bits per word (= N_SIMD * W for FINN layers)
    depth: int  # words
    layer: int  # originating NN layer id (for intra-layer packing)
    name: str = ""

    @property
    def bits(self) -> int:
        return self.width * self.depth


class PackingProblem:
    """Immutable problem instance: a set of buffers + hardware constraints.

    ``ocm`` selects the heterogeneous model (kind lane active, costs in
    inventory units); without it the problem is the paper's single-kind
    model over ``bram`` (default BRAM18), with unit weight 1.
    """

    def __init__(
        self,
        buffers: Sequence[Buffer],
        bram: BRAMSpec | None = None,
        max_items: int = 4,
        name: str = "",
        ocm: OCMInventory | None = None,
    ):
        if not buffers:
            raise ValueError("PackingProblem needs at least one buffer")
        if max_items < 1:
            raise ValueError("max_items must be >= 1")
        if ocm is not None and bram is not None:
            raise ValueError("pass either bram= (single kind) or ocm=, not both")
        self.buffers = tuple(buffers)
        self.ocm = ocm
        if ocm is not None:
            self.ram_kinds = ocm.kinds
            self.kind_counts = tuple(int(c) for c in ocm.counts)
            self.kind_weights = ocm.weights
            self.cost_unit_bits = ocm.unit_bits
            k0 = ocm.kinds[0]
            self.bram = BRAMSpec(modes=k0.modes, capacity_bits=k0.capacity_bits)
        else:
            self.bram = bram or BRAMSpec()
            self.ram_kinds = (
                RAMKind("RAM", tuple(self.bram.modes), self.bram.capacity_bits),
            )
            self.kind_counts = (-1,)
            self.kind_weights = (1,)
            self.cost_unit_bits = self.bram.capacity_bits
        self.n_kinds = len(self.ram_kinds)
        self.max_items = int(max_items)
        self.name = name
        self.widths = np.asarray([b.width for b in buffers], dtype=np.int64)
        self.depths = np.asarray([b.depth for b in buffers], dtype=np.int64)
        self.layers = np.asarray([b.layer for b in buffers], dtype=np.int64)
        self.total_bits = int(np.sum(self.widths * self.depths))
        self._mode_w = self.bram.mode_widths  # (M,) kind-0 tables
        self._mode_d = self.bram.mode_depths  # (M,)
        # per-kind precomputed mode tables: the single source every cost
        # evaluator (scalar, numpy, jnp ref, Pallas) derives from
        self._kind_modes_py = tuple(tuple(k.modes) for k in self.ram_kinds)
        self._kind_mode_w = [
            np.asarray([m[0] for m in k.modes], dtype=np.int64)
            for k in self.ram_kinds
        ]
        self._kind_mode_d = [
            np.asarray([m[1] for m in k.modes], dtype=np.int64)
            for k in self.ram_kinds
        ]
        self.kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...] = (
            tuple(
                (int(w), tuple(k.modes))
                for w, k in zip(self.kind_weights, self.ram_kinds)
            )
        )
        self._kind_weights_arr = np.asarray(self.kind_weights, dtype=np.int64)
        self._kind_counts_arr = np.asarray(self.kind_counts, dtype=np.int64)
        self._any_bounded = bool(np.any(self._kind_counts_arr >= 0))
        self._kind_caps = np.asarray(
            [k.capacity_bits for k in self.ram_kinds], dtype=np.int64
        )
        self._cost_caches: list[dict[tuple[int, int], tuple[int, int, int, int]]]
        self._cost_caches = [dict() for _ in range(self.n_kinds)]
        # python-int copies for the scalar hot path (numpy scalars are slow)
        self.widths_py = tuple(int(w) for w in self.widths)
        self.depths_py = tuple(int(d) for d in self.depths)
        self.layers_py = tuple(int(l) for l in self.layers)
        self.bits_py = tuple(w * d for w, d in zip(self.widths_py, self.depths_py))

    @property
    def n(self) -> int:
        return len(self.buffers)

    # ------------------------------------------------------------------ cost
    def bin_cost_many(
        self, widths: np.ndarray, heights: np.ndarray, kinds: np.ndarray | None = None
    ) -> np.ndarray:
        """Vectorized unit cost for bins of given (width, height), best mode.

        ``kinds`` selects the per-bin RAM kind (default: kind 0, the paper's
        homogeneous path).  Costs are in inventory units (primitives x
        kind weight); single-kind problems have weight 1."""
        if kinds is None:
            w = np.asarray(widths, dtype=np.int64)[..., None]
            h = np.asarray(heights, dtype=np.int64)[..., None]
            per_mode = -(-w // self._mode_w) * -(-h // self._mode_d)  # ceil div
            c = np.min(per_mode, axis=-1)
            w0 = self.kind_weights[0]
            return c * w0 if w0 != 1 else c
        return self.bin_primitives_many(widths, heights, kinds, weighted=True)

    def bin_primitives_many(
        self,
        widths: np.ndarray,
        heights: np.ndarray,
        kinds: np.ndarray,
        weighted: bool = False,
    ) -> np.ndarray:
        """Vectorized per-kind primitive count (or unit cost if ``weighted``)."""
        w = np.asarray(widths, dtype=np.int64)[..., None]
        h = np.asarray(heights, dtype=np.int64)[..., None]
        k = np.asarray(kinds)
        out = np.zeros(np.broadcast(w[..., 0], k).shape, dtype=np.int64)
        for ki in range(self.n_kinds):
            per_mode = -(-w // self._kind_mode_w[ki]) * -(-h // self._kind_mode_d[ki])
            c = np.min(per_mode, axis=-1)
            if weighted and self.kind_weights[ki] != 1:
                c = c * self.kind_weights[ki]
            out = np.where(k == ki, c, out)
        return out

    def _cost_mode_gap(
        self, width: int, height: int, kind: int = 0
    ) -> tuple[int, int, int, int]:
        """(unit_cost, best_mode_index, grid_gap, primitives) for a bin.

        Pure-python scalar hot path with per-kind memoization — called
        millions of times inside NFD/GA/SA inner loops.  ``unit_cost`` is
        ``primitives * kind_weight`` (weight 1 on the default path)."""
        cache = self._cost_caches[kind]
        key = (width, height)
        hit = cache.get(key)
        if hit is not None:
            return hit
        best_cost = 1 << 62
        best_m = 0
        modes = self._kind_modes_py[kind]
        for m, (mw, md) in enumerate(modes):
            c = -(-width // mw) * -(-height // md)
            if c < best_cost:
                best_cost = c
                best_m = m
        md = modes[best_m][1]
        gap = -(-height // md) * md - height
        out = (best_cost * self.kind_weights[kind], best_m, gap, best_cost)
        cache[key] = out
        return out

    def bin_cost(self, width: int, height: int, kind: int = 0) -> int:
        return self._cost_mode_gap(width, height, kind)[0]

    def bin_primitives(self, width: int, height: int, kind: int = 0) -> int:
        """Raw primitive count of the bin on the given RAM kind."""
        return self._cost_mode_gap(width, height, kind)[3]

    def bin_mode(self, width: int, height: int, kind: int = 0) -> tuple[int, int]:
        """The (mode_width, mode_depth) minimizing primitive count."""
        m = self._cost_mode_gap(width, height, kind)[1]
        return self._kind_modes_py[kind][m]

    def grid_gap(self, width: int, height: int, kind: int = 0) -> int:
        """Unused depth rows on the RAM grid under the best mode (NFD's gap)."""
        return self._cost_mode_gap(width, height, kind)[2]

    def best_kind(self, width: int, height: int) -> int:
        """The kind with minimal unit cost for this geometry (ties: lowest)."""
        if self.n_kinds == 1:
            return 0
        return min(
            range(self.n_kinds), key=lambda k: self._cost_mode_gap(width, height, k)[0]
        )

    def overflow_units(self, used: np.ndarray) -> np.ndarray:
        """Unit-weighted primitive usage beyond the inventory counts.

        ``used`` is (..., n_kinds); unbounded kinds (count < 0) never
        overflow.  The single source for the overflow formula — GA fitness,
        SA acceptance, and portfolio migration all score through it.
        """
        over = np.maximum(used - self._kind_counts_arr, 0)
        over = np.where(self._kind_counts_arr < 0, 0, over)
        return (over * self._kind_weights_arr).sum(axis=-1)

    def bin_stats(self, items: Sequence[int], kind: int = 0) -> tuple[int, int, int]:
        """(width, height, unit_cost) of a bin holding the given buffers."""
        w = 0
        h = 0
        for i in items:
            wi = self.widths_py[i]
            if wi > w:
                w = wi
            h += self.depths_py[i]
        return w, h, self._cost_mode_gap(w, h, kind)[0]

    # -------------------------------------------------------------- baseline
    def singleton_solution(self) -> "Solution":
        """The FINN-style unpacked baseline: one buffer per bin (kind 0)."""
        return Solution(self, [[i] for i in range(self.n)])

    def baseline_cost(self) -> int:
        return int(np.sum(self.bin_cost_many(self.widths, self.depths)))

    def lower_bound(self) -> int:
        """Information-theoretic minimum cost in units (capacity bound)."""
        return -(-self.total_bits // self.cost_unit_bits)

    def fingerprint(self) -> str:
        """Content hash over everything that affects packing outcomes.

        Two problems with equal fingerprints are interchangeable to every
        solver: same buffer multiset (in order), same cardinality bound,
        same RAM kinds / mode tables / inventory counts.  Names are
        excluded, so renamed duplicates inside a DSE sweep still dedup
        (``core.dse.pack_sweep`` keys its solution cache on this).
        """
        h = hashlib.blake2b(digest_size=16)
        h.update(self.widths.tobytes())
        h.update(self.depths.tobytes())
        h.update(self.layers.tobytes())
        h.update(repr((self.max_items, self.kind_counts, self.kind_tables)).encode())
        return h.hexdigest()


# geometry-matrix column indices (Solution._geom)
_GW, _GH, _GCOST, _GBITS, _GNL, _GPRIM = range(6)


class Solution:
    """A packing: partition of buffer indices into bins, plus a kind lane.

    The representation is a list of bins, each a list of buffer indices,
    with a parallel int64 ``kinds`` array assigning each bin a RAM kind
    (all zeros on single-kind problems — the kind lane then never affects
    costs or RNG streams).

    Per-bin aggregates live in a cached ``(nbins, 6)`` int64 *geometry
    matrix* with columns ``(width, height, unit_cost, bits, distinct_layers,
    primitives)`` and a parallel dirty mask.  Mutation operators that touch
    only a few bins (``buffer_swap``, ``nfd_repack``, kind reassignment)
    preserve the rows of untouched bins and mark the rest dirty via
    :meth:`touch` (or build the child solution with :meth:`_with_geometry`),
    so ``cost()`` and friends cost O(touched bins) of Python plus vectorized
    numpy over the rest — instead of the seed's full O(n buffers) rescan per
    evaluation.  ``cost_full()`` recomputes everything from scratch and is
    the reference the incremental path is tested against.

    Code that mutates ``bins`` or ``kinds`` directly must call :meth:`touch`
    with the affected bin indices (or :meth:`invalidate` wholesale) — the
    aggregate methods trust the cache.
    """

    __slots__ = (
        "problem", "bins", "kinds", "_geom", "_dirty", "_any_dirty", "_total_cost",
    )

    def __init__(
        self,
        problem: PackingProblem,
        bins: Iterable[Iterable[int]],
        kinds: Iterable[int] | None = None,
    ):
        self.problem = problem
        materialized = [list(b) for b in bins]
        if kinds is None:
            self.bins = [b for b in materialized if b]
            self.kinds = np.zeros(len(self.bins), dtype=np.int64)
        else:
            ks = np.asarray(list(kinds), dtype=np.int64)
            if len(ks) != len(materialized):
                raise ValueError("kinds must align with bins")
            live = [i for i, b in enumerate(materialized) if b]
            self.bins = [materialized[i] for i in live]
            self.kinds = ks[live]
        n = len(self.bins)
        self._geom = np.empty((n, 6), dtype=np.int64)
        self._dirty = np.ones(n, dtype=bool)
        self._any_dirty = True
        self._total_cost: int | None = None

    @classmethod
    def _with_geometry(
        cls,
        problem: PackingProblem,
        bins: list[list[int]],
        geom: np.ndarray,
        dirty: np.ndarray,
        kinds: np.ndarray | None = None,
    ) -> "Solution":
        """Internal fast constructor: ``bins`` are non-empty lists taken by
        reference, ``geom``/``dirty``/``kinds`` aligned and owned by the new
        solution (``kinds=None`` -> all kind 0)."""
        self = object.__new__(cls)
        self.problem = problem
        self.bins = bins
        self.kinds = (
            kinds if kinds is not None else np.zeros(len(bins), dtype=np.int64)
        )
        self._geom = geom
        self._dirty = dirty
        self._any_dirty = bool(dirty.any())
        self._total_cost = None
        return self

    def state_dict(self) -> dict:
        """JSON-able serialization of the packing itself: bins + kind lane.

        Geometry caches are derived state and deliberately not serialized —
        a solution rebuilt by :meth:`from_state_dict` starts cold and
        re-derives the exact same integer costs (the checkpoint/resume
        layer in ``core.resume`` round-trips through this pair).
        """
        return {
            "bins": [[int(i) for i in b] for b in self.bins],
            "kinds": [int(k) for k in self.kinds],
        }

    @classmethod
    def from_state_dict(cls, problem: PackingProblem, state: dict) -> "Solution":
        return cls(problem, state["bins"], state["kinds"])

    def copy(self) -> "Solution":
        out = Solution._with_geometry(
            self.problem,
            [list(b) for b in self.bins],
            self._geom.copy(),
            self._dirty.copy(),
            self.kinds.copy(),
        )
        out._total_cost = self._total_cost
        return out

    # ----------------------------------------------------- geometry protocol
    def _refresh(self) -> None:
        """Recompute the geometry rows of dirty bins (O(touched buffers))."""
        if not self._any_dirty:
            return
        p = self.problem
        widths, depths = p.widths_py, p.depths_py
        bits, layers = p.bits_py, p.layers_py
        cmg = p._cost_mode_gap
        hetero = p.n_kinds > 1
        ks = self.kinds
        g = self._geom
        bins = self.bins
        for bi in np.flatnonzero(self._dirty):
            items = bins[bi]
            w = 0
            h = 0
            nb = 0
            for i in items:
                wi = widths[i]
                if wi > w:
                    w = wi
                h += depths[i]
                nb += bits[i]
            c = cmg(w, h, int(ks[bi])) if hetero else cmg(w, h)
            row = g[bi]
            row[_GW] = w
            row[_GH] = h
            row[_GCOST] = c[0]
            row[_GBITS] = nb
            row[_GNL] = len({layers[i] for i in items})
            row[_GPRIM] = c[3]
        self._dirty[:] = False
        self._any_dirty = False

    def touch(self, *bin_indices: int) -> None:
        """Mark bins dirty after their contents (or kind) were mutated."""
        for bi in bin_indices:
            self._dirty[bi] = True
        self._any_dirty = True
        self._total_cost = None

    def set_kind(self, bin_index: int, kind: int) -> None:
        """Reassign one bin's RAM kind (cache-consistent)."""
        self.kinds[bin_index] = kind
        self.touch(bin_index)

    def invalidate(self) -> None:
        """Discard every cached row (after wholesale ``bins`` surgery).

        If the bin count changed, the kind lane is re-aligned by truncation /
        zero-padding — callers doing wholesale surgery own the kind values."""
        n = len(self.bins)
        if n != self._geom.shape[0]:
            self._geom = np.empty((n, 6), dtype=np.int64)
            self._dirty = np.ones(n, dtype=bool)
            old = self.kinds
            self.kinds = np.zeros(n, dtype=np.int64)
            self.kinds[: min(n, len(old))] = old[: min(n, len(old))]
        else:
            self._dirty[:] = True
        self._any_dirty = True
        self._total_cost = None

    def drop_empty(self) -> None:
        """Remove empty bins (and their geometry/kind rows) left by moves."""
        if all(self.bins):
            return
        live = np.asarray([bool(b) for b in self.bins])
        self.bins = [b for b in self.bins if b]
        self._geom = self._geom[live]
        self._dirty = self._dirty[live]
        self.kinds = self.kinds[live]
        self._total_cost = None

    def fill_geometry(self, wrow: np.ndarray, hrow: np.ndarray) -> int:
        """Write per-bin (width, height) into int32 rows, zero-padding the
        tail — the population-matrix update feeding the batched fitness
        kernel.  Returns the number of live bins."""
        self._refresh()
        nb = len(self.bins)
        wrow[:nb] = self._geom[:, _GW]
        hrow[:nb] = self._geom[:, _GH]
        wrow[nb:] = 0
        hrow[nb:] = 0
        return nb

    def fill_kinds(self, krow: np.ndarray) -> int:
        """Write the per-bin kind lane into an int32 row, zero-padding the
        tail (padded slots have width 0 and cost nothing on any kind)."""
        nb = len(self.bins)
        krow[:nb] = self.kinds
        krow[nb:] = 0
        return nb

    def scan_bin_geometry(
        self, bin_indices: Sequence[int]
    ) -> tuple[list[int], list[int]]:
        """Fresh (widths, heights) of the given bins from their *current*
        contents, bypassing (and not populating) the geometry cache.

        This is the "new geometry" probe of the in-place SA move protocol:
        after a move sequence mutated ``bins`` without ``touch()``, the
        cached rows still describe the pre-move state while this scan
        describes the candidate — the pair feeds the delta-cost kernel.
        An emptied bin reports (0, 0), which costs nothing.
        """
        widths, depths = self.problem.widths_py, self.problem.depths_py
        ws: list[int] = []
        hs: list[int] = []
        bins = self.bins
        for bi in bin_indices:
            w = 0
            h = 0
            for i in bins[bi]:
                wi = widths[i]
                if wi > w:
                    w = wi
                h += depths[i]
            ws.append(w)
            hs.append(h)
        return ws, hs

    # ------------------------------------------------------------ aggregates
    def cost(self) -> int:
        """Total cost in inventory units (the paper's BRAM count on the
        default single-kind path).

        O(dirty bins) row refresh + a vectorized sum; the seed implementation
        rescanned every buffer of every bin on each call."""
        if self._total_cost is None:
            self._refresh()
            self._total_cost = int(self._geom[:, _GCOST].sum())
        return self._total_cost

    def cost_full(self) -> int:
        """Seed-equivalent scalar evaluation: recompute every bin from its
        buffers, bypassing (and not populating) the geometry cache.  Used for
        cache-consistency tests and as the legacy benchmark baseline."""
        stats = self.problem.bin_stats
        if self.problem.n_kinds == 1:
            return sum(stats(b)[2] for b in self.bins)
        return sum(stats(b, int(k))[2] for b, k in zip(self.bins, self.kinds))

    def bin_costs(self) -> np.ndarray:
        self._refresh()
        return self._geom[:, _GCOST].copy()

    def used_primitives(self) -> np.ndarray:
        """Per-kind primitive usage, shape (n_kinds,) int64."""
        self._refresh()
        out = np.zeros(self.problem.n_kinds, dtype=np.int64)
        np.add.at(out, self.kinds, self._geom[:, _GPRIM])
        return out

    def inventory_overflow(self) -> int:
        """Unit-weighted primitive usage beyond the inventory counts.

        0 on problems without bounded counts (including every default
        single-kind problem); the engines fold this, scaled by their
        ``inventory_penalty``, into fitness / SA acceptance."""
        p = self.problem
        if not p._any_bounded:
            return 0
        return int(p.overflow_units(self.used_primitives()))

    def bin_efficiencies(self) -> np.ndarray:
        self._refresh()
        g = self._geom
        caps = self.problem._kind_caps[self.kinds]
        return g[:, _GBITS] / (g[:, _GPRIM] * caps.astype(np.float64))

    def bin_efficiencies_full(self) -> np.ndarray:
        """Seed-equivalent uncached scan (legacy benchmark baseline)."""
        p = self.problem
        bits_py = p.bits_py
        out = np.empty(len(self.bins), dtype=np.float64)
        for bi, b in enumerate(self.bins):
            k = int(self.kinds[bi])
            bits = sum(bits_py[i] for i in b)
            w, h, _ = p.bin_stats(b, k)
            prim = p.bin_primitives(w, h, k)
            out[bi] = bits / (prim * p.ram_kinds[k].capacity_bits)
        return out

    def efficiency(self) -> float:
        """Paper Eq. 1 generalized: stored bits / allocated RAM capacity."""
        return self.problem.total_bits / (self.cost() * self.problem.cost_unit_bits)

    def distinct_layers_per_bin(self) -> float:
        self._refresh()
        return float(self._geom[:, _GNL].sum()) / len(self.bins)

    def distinct_layers_per_bin_full(self) -> float:
        """Seed-equivalent uncached scan (legacy benchmark baseline)."""
        layers = self.problem.layers_py
        total = sum(len({layers[i] for i in b}) for b in self.bins)
        return total / len(self.bins)

    def max_items_per_bin(self) -> int:
        return max(len(b) for b in self.bins)

    # ------------------------------------------------------------ validation
    def validate(self, intra_layer: bool = False) -> None:
        """Raises if the packing is not implementable under the constraints."""
        p = self.problem
        seen: list[int] = sorted(i for b in self.bins for i in b)
        if seen != list(range(p.n)):
            raise ValueError("solution does not place every buffer exactly once")
        if len(self.kinds) != len(self.bins):
            raise ValueError("kind lane misaligned with bins")
        if len(self.kinds) and (
            int(self.kinds.min()) < 0 or int(self.kinds.max()) >= p.n_kinds
        ):
            raise ValueError("bin kind out of inventory range")
        for b in self.bins:
            if len(b) > p.max_items:
                raise ValueError(
                    f"bin of size {len(b)} exceeds cardinality {p.max_items}"
                )
            if intra_layer and len({int(p.layers[i]) for i in b}) > 1:
                raise ValueError("intra-layer constraint violated")

    def is_valid(self, intra_layer: bool = False) -> bool:
        try:
            self.validate(intra_layer=intra_layer)
            return True
        except ValueError:
            return False


def greedy_assign_kinds(sol: Solution) -> Solution:
    """Inventory-aware greedy kind assignment, in place (init heuristic).

    Every bin starts on its cheapest kind (which, for capacity-commensurate
    kinds like BRAM18 vs URAM288, is always the finest-grained one); while a
    bounded kind is over its count, the resident bin with the smallest
    unit-cost regret per freed primitive moves to a kind with room.  Leaves
    residual overflow — if no feasible move exists — to the engines'
    inventory penalty.  No-op on single-kind problems; consumes no RNG.
    """
    p = sol.problem
    if p.n_kinds == 1 or not p._any_bounded:
        return sol
    sol._refresh()
    nb = len(sol.bins)
    nk = p.n_kinds
    g = sol._geom
    wc = np.empty((nb, nk), dtype=np.int64)
    prim = np.empty((nb, nk), dtype=np.int64)
    for bi in range(nb):
        w, h = int(g[bi, _GW]), int(g[bi, _GH])
        for k in range(nk):
            c = p._cost_mode_gap(w, h, k)
            wc[bi, k] = c[0]
            prim[bi, k] = c[3]
    kinds = np.argmin(wc, axis=1).astype(np.int64)
    counts = p._kind_counts_arr
    used = np.zeros(nk, dtype=np.int64)
    ar = np.arange(nb)
    np.add.at(used, kinds, prim[ar, kinds])
    # move selection is vectorized over bins per candidate target kind:
    # large heterogeneous inits (hundreds of bins x population size) would
    # otherwise spend seconds in nested python loops
    for _ in range(nb + 1):
        over = (counts >= 0) & (used > counts)
        if not over.any():
            break
        cur_wc = wc[ar, kinds]
        cur_prim = prim[ar, kinds]
        movable = over[kinds] & (cur_prim > 0)
        best = None  # (regret per freed primitive, bin, target kind)
        for j in range(nk):
            cand = movable & (kinds != j)
            if counts[j] >= 0:
                cand &= used[j] + prim[:, j] <= counts[j]
            if not cand.any():
                continue
            regret = np.where(cand, (wc[:, j] - cur_wc) / cur_prim, np.inf)
            bi = int(np.argmin(regret))
            if best is None or regret[bi] < best[0]:
                best = (float(regret[bi]), bi, j)
        if best is None:
            break
        _, bi, j = best
        used[kinds[bi]] -= prim[bi, kinds[bi]]
        kinds[bi] = j
        used[j] += prim[bi, j]
    changed = np.flatnonzero(kinds != sol.kinds)
    if changed.size:
        sol.kinds[:] = kinds
        sol.touch(*[int(b) for b in changed])
    return sol


def encode_chain_items(
    solutions: Sequence["Solution"], max_items: int, n_slots: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Encode C solutions as padded (C, n_slots, max_items) item matrices.

    Slot (c, b) holds the buffer indices of chain c's bin b, ``-1``-padded;
    a parallel (C, n_slots) count matrix gives each bin's fill.  This is the
    fully-vectorized chain representation of the multi-chain annealer:
    buffer-swap moves become fancy-indexed row edits, applied to every chain
    at once.  Bin order and within-bin slot order are preserved, so
    ``decode_chain_items`` round-trips exactly.
    """
    c = len(solutions)
    nb = max(len(s.bins) for s in solutions)
    if n_slots is not None:
        nb = max(nb, n_slots)
    items = np.full((c, nb, max_items), -1, dtype=np.int32)
    counts = np.zeros((c, nb), dtype=np.int32)
    for k, s in enumerate(solutions):
        for b, binlist in enumerate(s.bins):
            items[k, b, : len(binlist)] = binlist
            counts[k, b] = len(binlist)
    return items, counts


def decode_chain_items(
    prob: PackingProblem,
    items_row: np.ndarray,
    counts_row: np.ndarray,
    kinds_row: np.ndarray | None = None,
) -> "Solution":
    """Decode one chain row (n_slots, max_items) back into a `Solution`.

    Empty slots are dropped (along with their kind-lane entries); the
    result's geometry cache starts cold and is recomputed from the buffers,
    so a decoded solution independently re-derives the cost the incremental
    chain bookkeeping arrived at (the engine's consistency tests rely on
    this property).
    """
    live = [b for b in range(len(counts_row)) if counts_row[b] > 0]
    bins = [
        [int(x) for x in items_row[b, : int(counts_row[b])]] for b in live
    ]
    kinds = None if kinds_row is None else [int(kinds_row[b]) for b in live]
    return Solution(prob, bins, kinds=kinds)


def encode_chain_geometry(
    solutions: Sequence["Solution"], n_slots: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode C solutions as padded (C, n_slots) int32 chain matrices.

    Row c holds the per-bin (width, height) of ``solutions[c]``, zero-padded
    — the multi-chain SA analogue of the GA's population matrices.  Returns
    (W, H, live-bin counts).
    """
    c = len(solutions)
    w = np.zeros((c, n_slots), dtype=np.int32)
    h = np.zeros((c, n_slots), dtype=np.int32)
    nb = np.zeros(c, dtype=np.int64)
    for i, s in enumerate(solutions):
        nb[i] = s.fill_geometry(w[i], h[i])
    return w, h, nb


def encode_chain_kinds(solutions: Sequence["Solution"], n_slots: int) -> np.ndarray:
    """Encode C solutions' kind lanes as a padded (C, n_slots) int32 matrix
    (padded slots get kind 0; they carry width 0 and cost nothing)."""
    c = len(solutions)
    k = np.zeros((c, n_slots), dtype=np.int32)
    for i, s in enumerate(solutions):
        s.fill_kinds(k[i])
    return k


# ------------------------------------------------------------ problem batches
def batch_group_key(prob: PackingProblem) -> tuple:
    """Hashable cost-model signature for cross-problem batching.

    Problems sharing this key evaluate on identical per-kind mode tables and
    unit weights, so their bins can ride through one batched kernel call
    (``kind_tables`` are static/jit-cached arguments); inventory *counts* may
    differ per problem — they only enter the host-side overflow penalty.
    ``core.dse.pack_sweep`` groups a mixed fleet by this key; see
    docs/DESIGN.md section 10.
    """
    return (prob.ram_kinds, prob.kind_tables)


@dataclasses.dataclass
class ProblemBatch:
    """A fleet of problems padded to one ``(n_max, cap_max)`` envelope.

    The cross-problem analogue of the chain codecs above: per-buffer tables
    become zero-padded ``(P, n_max)`` matrices with a parallel boolean
    ``mask`` (True where a real buffer lives), and per-problem scalars become
    ``(P,)`` vectors.  All member problems must share one cost-model
    signature (:func:`batch_group_key`) — the shared ``kind_tables`` are what
    lets a whole fleet go through one batched kernel call — while buffer
    counts, cardinality bounds (``max_items``), and inventory *counts* vary
    per problem.  Padded lanes are masked by construction: a padded buffer
    slot has width 0 and a padded problem row costs nothing on any backend.

    ``ext_tables`` appends the sentinel column the vectorized engines index
    with (slot id ``n_max`` -> width 0 / depth 0 / layer -1), mirroring the
    single-problem ``np.append(prob.widths, 0)`` convention.
    """

    widths: np.ndarray      # (P, n_max) int64, zero beyond problem p's count
    depths: np.ndarray      # (P, n_max) int64
    layers: np.ndarray      # (P, n_max) int64, -1 padded
    mask: np.ndarray        # (P, n_max) bool — True where a real buffer lives
    n: np.ndarray           # (P,) live buffer counts
    max_items: np.ndarray   # (P,) per-problem cardinality bounds
    kind_tables: tuple      # shared ((unit_weight, modes), ...) across the fleet
    kind_counts: np.ndarray  # (P, K) inventory counts (-1 = unbounded)
    ram_kinds: tuple        # shared RAMKind tuple (decode needs capacities)
    has_ocm: tuple          # per problem: built with an OCMInventory?
    names: tuple            # per-problem names
    ocm_names: tuple        # per-problem inventory names ("" without ocm)

    @property
    def size(self) -> int:
        return int(self.widths.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.widths.shape[1])

    @property
    def cap_max(self) -> int:
        return int(self.max_items.max())

    @property
    def n_kinds(self) -> int:
        return len(self.kind_tables)

    @property
    def kind_weights(self) -> np.ndarray:
        return np.asarray([w for w, _ in self.kind_tables], dtype=np.int64)

    def ext_tables(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(widths, depths, layers) as ``(P, n_max + 1)`` lookup tables whose
        last column is the empty-slot sentinel (0 / 0 / -1)."""
        p = self.size
        w = np.concatenate([self.widths, np.zeros((p, 1), np.int64)], axis=1)
        d = np.concatenate([self.depths, np.zeros((p, 1), np.int64)], axis=1)
        l = np.concatenate([self.layers, np.full((p, 1), -1, np.int64)], axis=1)
        return w, d, l

    def overflow_rows(self, used: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Unit-weighted inventory overflow with per-row counts.

        ``used`` is (R, K) per-kind primitive usage, ``rows`` maps each row
        to its problem index — the fleet generalization of
        :meth:`PackingProblem.overflow_units`.
        """
        counts = self.kind_counts[rows]
        over = np.maximum(used - counts, 0)
        over = np.where(counts < 0, 0, over)
        return (over * self.kind_weights).sum(axis=-1)


def encode_problem_batch(problems: Sequence[PackingProblem]) -> ProblemBatch:
    """Pad a fleet of cost-model-compatible problems into a `ProblemBatch`.

    Raises ``ValueError`` on an empty fleet or mixed cost models (different
    RAM kinds / mode tables) — callers solving a mixed fleet should first
    group by :func:`batch_group_key` (``pack_sweep`` does).
    """
    if not problems:
        raise ValueError("encode_problem_batch needs at least one problem")
    key = batch_group_key(problems[0])
    for prob in problems[1:]:
        if batch_group_key(prob) != key:
            raise ValueError(
                "problems mix cost models (RAM kinds / mode tables); group "
                "them with batch_group_key before batching"
            )
    p = len(problems)
    n_max = max(prob.n for prob in problems)
    widths = np.zeros((p, n_max), dtype=np.int64)
    depths = np.zeros((p, n_max), dtype=np.int64)
    layers = np.full((p, n_max), -1, dtype=np.int64)
    mask = np.zeros((p, n_max), dtype=bool)
    for j, prob in enumerate(problems):
        widths[j, : prob.n] = prob.widths
        depths[j, : prob.n] = prob.depths
        layers[j, : prob.n] = prob.layers
        mask[j, : prob.n] = True
    return ProblemBatch(
        widths=widths,
        depths=depths,
        layers=layers,
        mask=mask,
        n=np.asarray([prob.n for prob in problems], dtype=np.int64),
        max_items=np.asarray([prob.max_items for prob in problems], dtype=np.int64),
        kind_tables=problems[0].kind_tables,
        kind_counts=np.stack([prob._kind_counts_arr for prob in problems]),
        ram_kinds=problems[0].ram_kinds,
        has_ocm=tuple(prob.ocm is not None for prob in problems),
        names=tuple(prob.name for prob in problems),
        ocm_names=tuple(
            prob.ocm.name if prob.ocm is not None else "" for prob in problems
        ),
    )


def decode_problem_batch(batch: ProblemBatch) -> list[PackingProblem]:
    """Reconstruct the problem list from a `ProblemBatch` (codec inverse).

    Round-trips everything a solver can observe: buffer geometry/layers (in
    order), ``max_items``, RAM kinds and mode tables, inventory counts, and
    names.  Per-buffer ``Buffer.name`` labels are not carried by the batch
    and come back empty.
    """
    out: list[PackingProblem] = []
    for j in range(batch.size):
        nj = int(batch.n[j])
        bufs = [
            Buffer(
                width=int(batch.widths[j, i]),
                depth=int(batch.depths[j, i]),
                layer=int(batch.layers[j, i]),
            )
            for i in range(nj)
        ]
        if batch.has_ocm[j]:
            ocm = OCMInventory(
                kinds=batch.ram_kinds,
                counts=tuple(int(x) for x in batch.kind_counts[j]),
                name=batch.ocm_names[j],
            )
            prob = PackingProblem(
                bufs, max_items=int(batch.max_items[j]),
                name=batch.names[j], ocm=ocm,
            )
        else:
            k0 = batch.ram_kinds[0]
            prob = PackingProblem(
                bufs,
                bram=BRAMSpec(modes=tuple(k0.modes), capacity_bits=k0.capacity_bits),
                max_items=int(batch.max_items[j]),
                name=batch.names[j],
            )
        out.append(prob)
    return out


@dataclasses.dataclass
class PackingResult:
    """Outcome of one packer run (algorithm-agnostic)."""

    solution: Solution
    cost: int
    efficiency: float
    wall_time_s: float
    algorithm: str
    # (seconds since start, best cost so far); on heterogeneous problems the
    # value is the inventory-penalized cost, keeping the curve monotone
    trace: list[tuple[float, int]]
    iterations: int
    params: dict

    @property
    def baseline_cost(self) -> int:
        return self.solution.problem.baseline_cost()

    @property
    def baseline_efficiency(self) -> float:
        p = self.solution.problem
        return p.total_bits / (p.baseline_cost() * p.cost_unit_bits)

    @property
    def delta_bram(self) -> float:
        """Paper Table 4's memory-footprint reduction factor."""
        return self.baseline_cost / max(self.cost, 1)

    def time_to_within(self, frac: float = 0.01) -> float:
        """Paper's convergence metric: time to reach within `frac` of best."""
        target = self.cost * (1.0 + frac)
        for t, c in self.trace:
            if c <= target:
                return t
        return self.wall_time_s

    def summary(self) -> str:
        return (
            f"{self.algorithm}: cost={self.cost} BRAM "
            f"(baseline {self.baseline_cost}, x{self.delta_bram:.2f} smaller), "
            f"eff={self.efficiency * 100:.1f}% "
            f"(baseline {self.baseline_efficiency * 100:.1f}%), "
            f"t={self.wall_time_s:.2f}s"
        )


def buffers_from_shape_rows(
    rows: Sequence[tuple[int, tuple[int, int, int]]]
) -> list[Buffer]:
    """Expand Table-1-style rows ``(N_PE, (N_SIMD, D, W))`` into buffers.

    Each row describes one layer; the row's ``N_PE`` parameter memories all
    belong to that layer (relevant for intra-layer packing).
    """
    out: list[Buffer] = []
    for layer, (n_pe, (n_simd, depth, wbits)) in enumerate(rows):
        for pe in range(n_pe):
            out.append(
                Buffer(
                    width=n_simd * wbits,
                    depth=depth,
                    layer=layer,
                    name=f"L{layer}PE{pe}",
                )
            )
    return out
