"""Core data model for the CNN-parameter-memory -> FPGA-OCM bin packing problem.

Faithful to Kroes et al., "Evolutionary Bin Packing for Memory-Efficient
Dataflow Inference Acceleration on FPGA" (2020):

* A *buffer* is one CNN parameter memory with a fixed word width (bits) and
  depth (words).  In FINN-style accelerators a layer with parallelism
  ``N_PE x (N_SIMD, D, W)`` contributes ``N_PE`` buffers of width
  ``N_SIMD * W`` bits and depth ``D``.
* A *bin* is a group of buffers co-located in one composed block-RAM
  structure.  Buffers in a bin are stacked in depth; the bin's width is the
  maximum buffer width and its height the sum of buffer depths.  A bin may
  hold at most ``max_items`` buffers (the paper's cardinality constraint,
  derived from the 2 physical BRAM ports; the paper evaluates with 4).
* A Xilinx BRAM18 stores 18 Kib and supports aspect-ratio modes
  ``1x16K, 2x8K, 4x4K, 9x2K, 18x1K, 36x512``.  A (width x height) bin is
  implemented by tiling BRAMs in one mode; the implementation cost is

      cost(w, h) = min_m ceil(w / w_m) * ceil(h / d_m)

  and the paper's Eq. 1 mapping efficiency generalizes to

      E = stored_bits / (cost * CAPACITY_BITS).

The model is bit-exact reproducible in software; `tests/test_core_problem.py`
pins it against every published baseline efficiency in the paper's Table 4.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Xilinx BRAM18: 16K data bits + 2K parity bits.  Parity bits are usable as
# data only for aspect widths >= 9, hence the capacity difference per mode.
BRAM18_MODES: tuple[tuple[int, int], ...] = (
    (1, 16384),
    (2, 8192),
    (4, 4096),
    (9, 2048),
    (18, 1024),
    (36, 512),
)
BRAM18_CAPACITY_BITS = 18 * 1024  # Eq. 1 denominator (18432), as in the paper


@dataclasses.dataclass(frozen=True)
class BRAMSpec:
    """A physical RAM primitive with configurable aspect-ratio modes."""

    modes: tuple[tuple[int, int], ...] = BRAM18_MODES
    capacity_bits: int = BRAM18_CAPACITY_BITS

    @property
    def mode_widths(self) -> np.ndarray:
        return np.asarray([m[0] for m in self.modes], dtype=np.int64)

    @property
    def mode_depths(self) -> np.ndarray:
        return np.asarray([m[1] for m in self.modes], dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class Buffer:
    """One logical parameter memory."""

    width: int  # bits per word (= N_SIMD * W for FINN layers)
    depth: int  # words
    layer: int  # originating NN layer id (for intra-layer packing)
    name: str = ""

    @property
    def bits(self) -> int:
        return self.width * self.depth


class PackingProblem:
    """Immutable problem instance: a set of buffers + hardware constraints."""

    def __init__(
        self,
        buffers: Sequence[Buffer],
        bram: BRAMSpec | None = None,
        max_items: int = 4,
        name: str = "",
    ):
        if not buffers:
            raise ValueError("PackingProblem needs at least one buffer")
        if max_items < 1:
            raise ValueError("max_items must be >= 1")
        self.buffers = tuple(buffers)
        self.bram = bram or BRAMSpec()
        self.max_items = int(max_items)
        self.name = name
        self.widths = np.asarray([b.width for b in buffers], dtype=np.int64)
        self.depths = np.asarray([b.depth for b in buffers], dtype=np.int64)
        self.layers = np.asarray([b.layer for b in buffers], dtype=np.int64)
        self.total_bits = int(np.sum(self.widths * self.depths))
        self._mode_w = self.bram.mode_widths  # (M,)
        self._mode_d = self.bram.mode_depths  # (M,)
        self._modes_py = tuple(self.bram.modes)  # fast scalar path
        self._cost_cache: dict[tuple[int, int], tuple[int, int, int]] = {}
        # python-int copies for the scalar hot path (numpy scalars are slow)
        self.widths_py = tuple(int(w) for w in self.widths)
        self.depths_py = tuple(int(d) for d in self.depths)
        self.layers_py = tuple(int(l) for l in self.layers)
        self.bits_py = tuple(w * d for w, d in zip(self.widths_py, self.depths_py))

    @property
    def n(self) -> int:
        return len(self.buffers)

    # ------------------------------------------------------------------ cost
    def bin_cost_many(self, widths: np.ndarray, heights: np.ndarray) -> np.ndarray:
        """Vectorized BRAM count for bins of given (width, height), best mode."""
        w = np.asarray(widths, dtype=np.int64)[..., None]
        h = np.asarray(heights, dtype=np.int64)[..., None]
        per_mode = -(-w // self._mode_w) * -(-h // self._mode_d)  # ceil div
        return np.min(per_mode, axis=-1)

    def _cost_mode_gap(self, width: int, height: int) -> tuple[int, int, int]:
        """(cost, best_mode_index, grid_gap) for a (width, height) bin.

        Pure-python scalar hot path with memoization — called millions of
        times inside NFD/GA/SA inner loops.
        """
        key = (width, height)
        hit = self._cost_cache.get(key)
        if hit is not None:
            return hit
        best_cost = 1 << 62
        best_m = 0
        for m, (mw, md) in enumerate(self._modes_py):
            c = -(-width // mw) * -(-height // md)
            if c < best_cost:
                best_cost = c
                best_m = m
        md = self._modes_py[best_m][1]
        gap = -(-height // md) * md - height
        out = (best_cost, best_m, gap)
        self._cost_cache[key] = out
        return out

    def bin_cost(self, width: int, height: int) -> int:
        return self._cost_mode_gap(width, height)[0]

    def bin_mode(self, width: int, height: int) -> tuple[int, int]:
        """The (mode_width, mode_depth) minimizing BRAM count for this bin."""
        m = self._cost_mode_gap(width, height)[1]
        return self._modes_py[m]

    def grid_gap(self, width: int, height: int) -> int:
        """Unused depth rows on the BRAM grid under the best mode (NFD's gap)."""
        return self._cost_mode_gap(width, height)[2]

    def bin_stats(self, items: Sequence[int]) -> tuple[int, int, int]:
        """(width, height, cost) of a bin holding the given buffer indices."""
        w = 0
        h = 0
        for i in items:
            wi = self.widths_py[i]
            if wi > w:
                w = wi
            h += self.depths_py[i]
        return w, h, self._cost_mode_gap(w, h)[0]

    # -------------------------------------------------------------- baseline
    def singleton_solution(self) -> "Solution":
        """The FINN-style unpacked baseline: one buffer per bin."""
        return Solution(self, [[i] for i in range(self.n)])

    def baseline_cost(self) -> int:
        return int(np.sum(self.bin_cost_many(self.widths, self.depths)))

    def lower_bound(self) -> int:
        """Information-theoretic minimum BRAM count (capacity bound)."""
        return -(-self.total_bits // self.bram.capacity_bits)


class Solution:
    """A packing: partition of buffer indices into bins.

    The representation is a list of bins, each a list of buffer indices.
    Aggregate statistics are computed with numpy for speed; GA/SA call
    ``cost()`` in their inner loop.
    """

    __slots__ = ("problem", "bins")

    def __init__(self, problem: PackingProblem, bins: Iterable[Iterable[int]]):
        self.problem = problem
        self.bins = [list(b) for b in bins if len(list(b)) > 0]

    def copy(self) -> "Solution":
        return Solution(self.problem, [list(b) for b in self.bins])

    # ------------------------------------------------------------ aggregates
    def cost(self) -> int:
        """Total BRAM count (the paper's primary objective)."""
        stats = self.problem.bin_stats
        return sum(stats(b)[2] for b in self.bins)

    def bin_costs(self) -> np.ndarray:
        stats = self.problem.bin_stats
        return np.asarray([stats(b)[2] for b in self.bins], dtype=np.int64)

    def bin_efficiencies(self) -> np.ndarray:
        p = self.problem
        bits_py = p.bits_py
        cap = p.bram.capacity_bits
        out = np.empty(len(self.bins), dtype=np.float64)
        for bi, b in enumerate(self.bins):
            bits = sum(bits_py[i] for i in b)
            out[bi] = bits / (p.bin_stats(b)[2] * cap)
        return out

    def efficiency(self) -> float:
        """Paper Eq. 1 generalized: stored bits / allocated BRAM capacity."""
        return self.problem.total_bits / (self.cost() * self.problem.bram.capacity_bits)

    def distinct_layers_per_bin(self) -> float:
        layers = self.problem.layers_py
        total = sum(len({layers[i] for i in b}) for b in self.bins)
        return total / len(self.bins)

    def max_items_per_bin(self) -> int:
        return max(len(b) for b in self.bins)

    # ------------------------------------------------------------ validation
    def validate(self, intra_layer: bool = False) -> None:
        """Raises if the packing is not implementable under the constraints."""
        p = self.problem
        seen: list[int] = sorted(i for b in self.bins for i in b)
        if seen != list(range(p.n)):
            raise ValueError("solution does not place every buffer exactly once")
        for b in self.bins:
            if len(b) > p.max_items:
                raise ValueError(
                    f"bin of size {len(b)} exceeds cardinality {p.max_items}"
                )
            if intra_layer and len({int(p.layers[i]) for i in b}) > 1:
                raise ValueError("intra-layer constraint violated")

    def is_valid(self, intra_layer: bool = False) -> bool:
        try:
            self.validate(intra_layer=intra_layer)
            return True
        except ValueError:
            return False


@dataclasses.dataclass
class PackingResult:
    """Outcome of one packer run (algorithm-agnostic)."""

    solution: Solution
    cost: int
    efficiency: float
    wall_time_s: float
    algorithm: str
    trace: list[tuple[float, int]]  # (seconds since start, best cost so far)
    iterations: int
    params: dict

    @property
    def baseline_cost(self) -> int:
        return self.solution.problem.baseline_cost()

    @property
    def baseline_efficiency(self) -> float:
        p = self.solution.problem
        return p.total_bits / (p.baseline_cost() * p.bram.capacity_bits)

    @property
    def delta_bram(self) -> float:
        """Paper Table 4's memory-footprint reduction factor."""
        return self.baseline_cost / max(self.cost, 1)

    def time_to_within(self, frac: float = 0.01) -> float:
        """Paper's convergence metric: time to reach within `frac` of best."""
        target = self.cost * (1.0 + frac)
        for t, c in self.trace:
            if c <= target:
                return t
        return self.wall_time_s

    def summary(self) -> str:
        return (
            f"{self.algorithm}: cost={self.cost} BRAM "
            f"(baseline {self.baseline_cost}, x{self.delta_bram:.2f} smaller), "
            f"eff={self.efficiency * 100:.1f}% "
            f"(baseline {self.baseline_efficiency * 100:.1f}%), "
            f"t={self.wall_time_s:.2f}s"
        )


def buffers_from_shape_rows(
    rows: Sequence[tuple[int, tuple[int, int, int]]]
) -> list[Buffer]:
    """Expand Table-1-style rows ``(N_PE, (N_SIMD, D, W))`` into buffers.

    Each row describes one layer; the row's ``N_PE`` parameter memories all
    belong to that layer (relevant for intra-layer packing).
    """
    out: list[Buffer] = []
    for layer, (n_pe, (n_simd, depth, wbits)) in enumerate(rows):
        for pe in range(n_pe):
            out.append(
                Buffer(
                    width=n_simd * wbits,
                    depth=depth,
                    layer=layer,
                    name=f"L{layer}PE{pe}",
                )
            )
    return out
