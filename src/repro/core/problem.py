"""Core data model for the CNN-parameter-memory -> FPGA-OCM bin packing problem.

Faithful to Kroes et al., "Evolutionary Bin Packing for Memory-Efficient
Dataflow Inference Acceleration on FPGA" (2020):

* A *buffer* is one CNN parameter memory with a fixed word width (bits) and
  depth (words).  In FINN-style accelerators a layer with parallelism
  ``N_PE x (N_SIMD, D, W)`` contributes ``N_PE`` buffers of width
  ``N_SIMD * W`` bits and depth ``D``.
* A *bin* is a group of buffers co-located in one composed block-RAM
  structure.  Buffers in a bin are stacked in depth; the bin's width is the
  maximum buffer width and its height the sum of buffer depths.  A bin may
  hold at most ``max_items`` buffers (the paper's cardinality constraint,
  derived from the 2 physical BRAM ports; the paper evaluates with 4).
* A Xilinx BRAM18 stores 18 Kib and supports aspect-ratio modes
  ``1x16K, 2x8K, 4x4K, 9x2K, 18x1K, 36x512``.  A (width x height) bin is
  implemented by tiling BRAMs in one mode; the implementation cost is

      cost(w, h) = min_m ceil(w / w_m) * ceil(h / d_m)

  and the paper's Eq. 1 mapping efficiency generalizes to

      E = stored_bits / (cost * CAPACITY_BITS).

The model is bit-exact reproducible in software; `tests/test_core_problem.py`
pins it against every published baseline efficiency in the paper's Table 4.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Xilinx BRAM18: 16K data bits + 2K parity bits.  Parity bits are usable as
# data only for aspect widths >= 9, hence the capacity difference per mode.
BRAM18_MODES: tuple[tuple[int, int], ...] = (
    (1, 16384),
    (2, 8192),
    (4, 4096),
    (9, 2048),
    (18, 1024),
    (36, 512),
)
BRAM18_CAPACITY_BITS = 18 * 1024  # Eq. 1 denominator (18432), as in the paper


@dataclasses.dataclass(frozen=True)
class BRAMSpec:
    """A physical RAM primitive with configurable aspect-ratio modes."""

    modes: tuple[tuple[int, int], ...] = BRAM18_MODES
    capacity_bits: int = BRAM18_CAPACITY_BITS

    @property
    def mode_widths(self) -> np.ndarray:
        return np.asarray([m[0] for m in self.modes], dtype=np.int64)

    @property
    def mode_depths(self) -> np.ndarray:
        return np.asarray([m[1] for m in self.modes], dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class Buffer:
    """One logical parameter memory."""

    width: int  # bits per word (= N_SIMD * W for FINN layers)
    depth: int  # words
    layer: int  # originating NN layer id (for intra-layer packing)
    name: str = ""

    @property
    def bits(self) -> int:
        return self.width * self.depth


class PackingProblem:
    """Immutable problem instance: a set of buffers + hardware constraints."""

    def __init__(
        self,
        buffers: Sequence[Buffer],
        bram: BRAMSpec | None = None,
        max_items: int = 4,
        name: str = "",
    ):
        if not buffers:
            raise ValueError("PackingProblem needs at least one buffer")
        if max_items < 1:
            raise ValueError("max_items must be >= 1")
        self.buffers = tuple(buffers)
        self.bram = bram or BRAMSpec()
        self.max_items = int(max_items)
        self.name = name
        self.widths = np.asarray([b.width for b in buffers], dtype=np.int64)
        self.depths = np.asarray([b.depth for b in buffers], dtype=np.int64)
        self.layers = np.asarray([b.layer for b in buffers], dtype=np.int64)
        self.total_bits = int(np.sum(self.widths * self.depths))
        self._mode_w = self.bram.mode_widths  # (M,)
        self._mode_d = self.bram.mode_depths  # (M,)
        self._modes_py = tuple(self.bram.modes)  # fast scalar path
        self._cost_cache: dict[tuple[int, int], tuple[int, int, int]] = {}
        # python-int copies for the scalar hot path (numpy scalars are slow)
        self.widths_py = tuple(int(w) for w in self.widths)
        self.depths_py = tuple(int(d) for d in self.depths)
        self.layers_py = tuple(int(l) for l in self.layers)
        self.bits_py = tuple(w * d for w, d in zip(self.widths_py, self.depths_py))

    @property
    def n(self) -> int:
        return len(self.buffers)

    # ------------------------------------------------------------------ cost
    def bin_cost_many(self, widths: np.ndarray, heights: np.ndarray) -> np.ndarray:
        """Vectorized BRAM count for bins of given (width, height), best mode."""
        w = np.asarray(widths, dtype=np.int64)[..., None]
        h = np.asarray(heights, dtype=np.int64)[..., None]
        per_mode = -(-w // self._mode_w) * -(-h // self._mode_d)  # ceil div
        return np.min(per_mode, axis=-1)

    def _cost_mode_gap(self, width: int, height: int) -> tuple[int, int, int]:
        """(cost, best_mode_index, grid_gap) for a (width, height) bin.

        Pure-python scalar hot path with memoization — called millions of
        times inside NFD/GA/SA inner loops.
        """
        key = (width, height)
        hit = self._cost_cache.get(key)
        if hit is not None:
            return hit
        best_cost = 1 << 62
        best_m = 0
        for m, (mw, md) in enumerate(self._modes_py):
            c = -(-width // mw) * -(-height // md)
            if c < best_cost:
                best_cost = c
                best_m = m
        md = self._modes_py[best_m][1]
        gap = -(-height // md) * md - height
        out = (best_cost, best_m, gap)
        self._cost_cache[key] = out
        return out

    def bin_cost(self, width: int, height: int) -> int:
        return self._cost_mode_gap(width, height)[0]

    def bin_mode(self, width: int, height: int) -> tuple[int, int]:
        """The (mode_width, mode_depth) minimizing BRAM count for this bin."""
        m = self._cost_mode_gap(width, height)[1]
        return self._modes_py[m]

    def grid_gap(self, width: int, height: int) -> int:
        """Unused depth rows on the BRAM grid under the best mode (NFD's gap)."""
        return self._cost_mode_gap(width, height)[2]

    def bin_stats(self, items: Sequence[int]) -> tuple[int, int, int]:
        """(width, height, cost) of a bin holding the given buffer indices."""
        w = 0
        h = 0
        for i in items:
            wi = self.widths_py[i]
            if wi > w:
                w = wi
            h += self.depths_py[i]
        return w, h, self._cost_mode_gap(w, h)[0]

    # -------------------------------------------------------------- baseline
    def singleton_solution(self) -> "Solution":
        """The FINN-style unpacked baseline: one buffer per bin."""
        return Solution(self, [[i] for i in range(self.n)])

    def baseline_cost(self) -> int:
        return int(np.sum(self.bin_cost_many(self.widths, self.depths)))

    def lower_bound(self) -> int:
        """Information-theoretic minimum BRAM count (capacity bound)."""
        return -(-self.total_bits // self.bram.capacity_bits)


# geometry-matrix column indices (Solution._geom)
_GW, _GH, _GCOST, _GBITS, _GNL = range(5)


class Solution:
    """A packing: partition of buffer indices into bins.

    The representation is a list of bins, each a list of buffer indices.

    Per-bin aggregates live in a cached ``(nbins, 5)`` int64 *geometry
    matrix* with columns ``(width, height, cost, bits, distinct_layers)`` and
    a parallel dirty mask.  Mutation operators that touch only a few bins
    (``buffer_swap``, ``nfd_repack``) preserve the rows of untouched bins and
    mark the rest dirty via :meth:`touch` (or build the child solution with
    :meth:`_with_geometry`), so ``cost()`` and friends cost O(touched bins)
    of Python plus vectorized numpy over the rest — instead of the seed's
    full O(n buffers) rescan per evaluation.  ``cost_full()`` recomputes
    everything from scratch and is the reference the incremental path is
    tested against.

    Code that mutates ``bins`` directly must call :meth:`touch` with the
    affected bin indices (or :meth:`invalidate` wholesale) — the aggregate
    methods trust the cache.
    """

    __slots__ = ("problem", "bins", "_geom", "_dirty", "_any_dirty", "_total_cost")

    def __init__(self, problem: PackingProblem, bins: Iterable[Iterable[int]]):
        self.problem = problem
        materialized = [list(b) for b in bins]
        self.bins = [b for b in materialized if b]
        n = len(self.bins)
        self._geom = np.empty((n, 5), dtype=np.int64)
        self._dirty = np.ones(n, dtype=bool)
        self._any_dirty = True
        self._total_cost: int | None = None

    @classmethod
    def _with_geometry(
        cls,
        problem: PackingProblem,
        bins: list[list[int]],
        geom: np.ndarray,
        dirty: np.ndarray,
    ) -> "Solution":
        """Internal fast constructor: ``bins`` are non-empty lists taken by
        reference, ``geom``/``dirty`` aligned and owned by the new solution."""
        self = object.__new__(cls)
        self.problem = problem
        self.bins = bins
        self._geom = geom
        self._dirty = dirty
        self._any_dirty = bool(dirty.any())
        self._total_cost = None
        return self

    def copy(self) -> "Solution":
        out = Solution._with_geometry(
            self.problem,
            [list(b) for b in self.bins],
            self._geom.copy(),
            self._dirty.copy(),
        )
        out._total_cost = self._total_cost
        return out

    # ----------------------------------------------------- geometry protocol
    def _refresh(self) -> None:
        """Recompute the geometry rows of dirty bins (O(touched buffers))."""
        if not self._any_dirty:
            return
        p = self.problem
        widths, depths = p.widths_py, p.depths_py
        bits, layers = p.bits_py, p.layers_py
        cmg = p._cost_mode_gap
        g = self._geom
        bins = self.bins
        for bi in np.flatnonzero(self._dirty):
            items = bins[bi]
            w = 0
            h = 0
            nb = 0
            for i in items:
                wi = widths[i]
                if wi > w:
                    w = wi
                h += depths[i]
                nb += bits[i]
            row = g[bi]
            row[_GW] = w
            row[_GH] = h
            row[_GCOST] = cmg(w, h)[0]
            row[_GBITS] = nb
            row[_GNL] = len({layers[i] for i in items})
        self._dirty[:] = False
        self._any_dirty = False

    def touch(self, *bin_indices: int) -> None:
        """Mark bins dirty after their contents were mutated in place."""
        for bi in bin_indices:
            self._dirty[bi] = True
        self._any_dirty = True
        self._total_cost = None

    def invalidate(self) -> None:
        """Discard every cached row (after wholesale ``bins`` surgery)."""
        n = len(self.bins)
        if n != self._geom.shape[0]:
            self._geom = np.empty((n, 5), dtype=np.int64)
            self._dirty = np.ones(n, dtype=bool)
        else:
            self._dirty[:] = True
        self._any_dirty = True
        self._total_cost = None

    def drop_empty(self) -> None:
        """Remove empty bins (and their geometry rows) left behind by moves."""
        if all(self.bins):
            return
        live = np.asarray([bool(b) for b in self.bins])
        self.bins = [b for b in self.bins if b]
        self._geom = self._geom[live]
        self._dirty = self._dirty[live]
        self._total_cost = None

    def fill_geometry(self, wrow: np.ndarray, hrow: np.ndarray) -> int:
        """Write per-bin (width, height) into int32 rows, zero-padding the
        tail — the population-matrix update feeding the batched fitness
        kernel.  Returns the number of live bins."""
        self._refresh()
        nb = len(self.bins)
        wrow[:nb] = self._geom[:, _GW]
        hrow[:nb] = self._geom[:, _GH]
        wrow[nb:] = 0
        hrow[nb:] = 0
        return nb

    def scan_bin_geometry(
        self, bin_indices: Sequence[int]
    ) -> tuple[list[int], list[int]]:
        """Fresh (widths, heights) of the given bins from their *current*
        contents, bypassing (and not populating) the geometry cache.

        This is the "new geometry" probe of the in-place SA move protocol:
        after a move sequence mutated ``bins`` without ``touch()``, the
        cached rows still describe the pre-move state while this scan
        describes the candidate — the pair feeds the delta-cost kernel.
        An emptied bin reports (0, 0), which costs nothing.
        """
        widths, depths = self.problem.widths_py, self.problem.depths_py
        ws: list[int] = []
        hs: list[int] = []
        bins = self.bins
        for bi in bin_indices:
            w = 0
            h = 0
            for i in bins[bi]:
                wi = widths[i]
                if wi > w:
                    w = wi
                h += depths[i]
            ws.append(w)
            hs.append(h)
        return ws, hs

    # ------------------------------------------------------------ aggregates
    def cost(self) -> int:
        """Total BRAM count (the paper's primary objective).

        O(dirty bins) row refresh + a vectorized sum; the seed implementation
        rescanned every buffer of every bin on each call."""
        if self._total_cost is None:
            self._refresh()
            self._total_cost = int(self._geom[:, _GCOST].sum())
        return self._total_cost

    def cost_full(self) -> int:
        """Seed-equivalent scalar evaluation: recompute every bin from its
        buffers, bypassing (and not populating) the geometry cache.  Used for
        cache-consistency tests and as the legacy benchmark baseline."""
        stats = self.problem.bin_stats
        return sum(stats(b)[2] for b in self.bins)

    def bin_costs(self) -> np.ndarray:
        self._refresh()
        return self._geom[:, _GCOST].copy()

    def bin_efficiencies(self) -> np.ndarray:
        self._refresh()
        cap = self.problem.bram.capacity_bits
        g = self._geom
        return g[:, _GBITS] / (g[:, _GCOST] * float(cap))

    def bin_efficiencies_full(self) -> np.ndarray:
        """Seed-equivalent uncached scan (legacy benchmark baseline)."""
        p = self.problem
        bits_py = p.bits_py
        cap = p.bram.capacity_bits
        out = np.empty(len(self.bins), dtype=np.float64)
        for bi, b in enumerate(self.bins):
            bits = sum(bits_py[i] for i in b)
            out[bi] = bits / (p.bin_stats(b)[2] * cap)
        return out

    def efficiency(self) -> float:
        """Paper Eq. 1 generalized: stored bits / allocated BRAM capacity."""
        return self.problem.total_bits / (self.cost() * self.problem.bram.capacity_bits)

    def distinct_layers_per_bin(self) -> float:
        self._refresh()
        return float(self._geom[:, _GNL].sum()) / len(self.bins)

    def distinct_layers_per_bin_full(self) -> float:
        """Seed-equivalent uncached scan (legacy benchmark baseline)."""
        layers = self.problem.layers_py
        total = sum(len({layers[i] for i in b}) for b in self.bins)
        return total / len(self.bins)

    def max_items_per_bin(self) -> int:
        return max(len(b) for b in self.bins)

    # ------------------------------------------------------------ validation
    def validate(self, intra_layer: bool = False) -> None:
        """Raises if the packing is not implementable under the constraints."""
        p = self.problem
        seen: list[int] = sorted(i for b in self.bins for i in b)
        if seen != list(range(p.n)):
            raise ValueError("solution does not place every buffer exactly once")
        for b in self.bins:
            if len(b) > p.max_items:
                raise ValueError(
                    f"bin of size {len(b)} exceeds cardinality {p.max_items}"
                )
            if intra_layer and len({int(p.layers[i]) for i in b}) > 1:
                raise ValueError("intra-layer constraint violated")

    def is_valid(self, intra_layer: bool = False) -> bool:
        try:
            self.validate(intra_layer=intra_layer)
            return True
        except ValueError:
            return False


def encode_chain_items(
    solutions: Sequence["Solution"], max_items: int, n_slots: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Encode C solutions as padded (C, n_slots, max_items) item matrices.

    Slot (c, b) holds the buffer indices of chain c's bin b, ``-1``-padded;
    a parallel (C, n_slots) count matrix gives each bin's fill.  This is the
    fully-vectorized chain representation of the multi-chain annealer:
    buffer-swap moves become fancy-indexed row edits, applied to every chain
    at once.  Bin order and within-bin slot order are preserved, so
    ``decode_chain_items`` round-trips exactly.
    """
    c = len(solutions)
    nb = max(len(s.bins) for s in solutions)
    if n_slots is not None:
        nb = max(nb, n_slots)
    items = np.full((c, nb, max_items), -1, dtype=np.int32)
    counts = np.zeros((c, nb), dtype=np.int32)
    for k, s in enumerate(solutions):
        for b, binlist in enumerate(s.bins):
            items[k, b, : len(binlist)] = binlist
            counts[k, b] = len(binlist)
    return items, counts


def decode_chain_items(
    prob: PackingProblem, items_row: np.ndarray, counts_row: np.ndarray
) -> "Solution":
    """Decode one chain row (n_slots, max_items) back into a `Solution`.

    Empty slots are dropped; the result's geometry cache starts cold and is
    recomputed from the buffers, so a decoded solution independently
    re-derives the cost the incremental chain bookkeeping arrived at (the
    engine's consistency tests rely on this property).
    """
    bins = [
        [int(x) for x in items_row[b, : int(counts_row[b])]]
        for b in range(len(counts_row))
        if counts_row[b] > 0
    ]
    return Solution(prob, bins)


def encode_chain_geometry(
    solutions: Sequence["Solution"], n_slots: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode C solutions as padded (C, n_slots) int32 chain matrices.

    Row c holds the per-bin (width, height) of ``solutions[c]``, zero-padded
    — the multi-chain SA analogue of the GA's population matrices.  Returns
    (W, H, live-bin counts).
    """
    c = len(solutions)
    w = np.zeros((c, n_slots), dtype=np.int32)
    h = np.zeros((c, n_slots), dtype=np.int32)
    nb = np.zeros(c, dtype=np.int64)
    for i, s in enumerate(solutions):
        nb[i] = s.fill_geometry(w[i], h[i])
    return w, h, nb


@dataclasses.dataclass
class PackingResult:
    """Outcome of one packer run (algorithm-agnostic)."""

    solution: Solution
    cost: int
    efficiency: float
    wall_time_s: float
    algorithm: str
    trace: list[tuple[float, int]]  # (seconds since start, best cost so far)
    iterations: int
    params: dict

    @property
    def baseline_cost(self) -> int:
        return self.solution.problem.baseline_cost()

    @property
    def baseline_efficiency(self) -> float:
        p = self.solution.problem
        return p.total_bits / (p.baseline_cost() * p.bram.capacity_bits)

    @property
    def delta_bram(self) -> float:
        """Paper Table 4's memory-footprint reduction factor."""
        return self.baseline_cost / max(self.cost, 1)

    def time_to_within(self, frac: float = 0.01) -> float:
        """Paper's convergence metric: time to reach within `frac` of best."""
        target = self.cost * (1.0 + frac)
        for t, c in self.trace:
            if c <= target:
                return t
        return self.wall_time_s

    def summary(self) -> str:
        return (
            f"{self.algorithm}: cost={self.cost} BRAM "
            f"(baseline {self.baseline_cost}, x{self.delta_bram:.2f} smaller), "
            f"eff={self.efficiency * 100:.1f}% "
            f"(baseline {self.baseline_efficiency * 100:.1f}%), "
            f"t={self.wall_time_s:.2f}s"
        )


def buffers_from_shape_rows(
    rows: Sequence[tuple[int, tuple[int, int, int]]]
) -> list[Buffer]:
    """Expand Table-1-style rows ``(N_PE, (N_SIMD, D, W))`` into buffers.

    Each row describes one layer; the row's ``N_PE`` parameter memories all
    belong to that layer (relevant for intra-layer packing).
    """
    out: list[Buffer] = []
    for layer, (n_pe, (n_simd, depth, wbits)) in enumerate(rows):
        for pe in range(n_pe):
            out.append(
                Buffer(
                    width=n_simd * wbits,
                    depth=depth,
                    layer=layer,
                    name=f"L{layer}PE{pe}",
                )
            )
    return out
