"""Crash-safe sweeps: checkpoint/resume codecs for the solver fleet.

The DSE regime the paper motivates (thousands of candidates x devices, the
sequel arXiv:2011.07317) turns a sweep into an hours-long job — which, until
this layer, lost everything on a crash or preemption.  PR 5 made every
engine a deterministic, iteration-budgeted state machine; this module wires
those state machines into ``checkpoint.CheckpointManager`` so that
``pack_sweep(..., checkpoint_dir=...)`` and ``pack_portfolio(...,
checkpoint_dir=...)`` can be SIGKILLed at any instant and resumed
(``resume=True``) **bit-identically**: the resumed run restarts from the
newest *valid* snapshot and lands on exactly the final best cost and
solution of the same-seed uninterrupted run.

Serialization contract (one codec per resumable state class, field lists
pinned as ``CODEC_*`` on the classes themselves):

* numpy arrays (chain/geometry matrices, cost vectors, patience counters)
  go into the checkpoint's ``arrays.npz`` under stable tree-path keys;
* everything else — ``np.random.Generator`` bit-generator states,
  ``Solution`` packings (bins + kind lanes via ``Solution.state_dict``),
  improvement traces, scalar counters, completed-candidate results keyed by
  task digest — goes into the JSON manifest ``extra``;
* scratch buffers and start-derived constants are NOT serialized: resume
  rebuilds the run state deterministically (same seeds, same construction
  order) and overwrites the resumable fields, which also provides the
  shape/layout template the restore validates against.

Snapshots are cut only at iteration/generation barriers (between engine
steps), so per-move transients (undo logs, proposal scratch) never need to
round-trip.  Because every engine is deterministic from any barrier state,
falling back to an *older* intact checkpoint after corruption still
converges to the bit-identical final result — the property the
fault-injection harness (``tests/faultinject.py`` + ``tools/sweep_resume.py``)
enforces.  Wall-clock fields (trace timestamps, ``wall_time_s``) restart on
resume and are exempt from the parity contract; see docs/DESIGN.md
section 12.
"""
from __future__ import annotations

import hashlib
import time
from typing import Callable, Sequence

import numpy as np

from ..checkpoint import CheckpointManager
from .problem import PackingProblem, PackingResult, Solution

# bump when the on-disk codec layout changes: a resume across formats must
# fail loudly, never half-restore
FORMAT = 1

_ENGINE_PREFIX = "eng/"


# ------------------------------------------------------------- JSON helpers
def _jsonify(obj):
    """Recursively convert numpy scalars/arrays and tuples to JSON values."""
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    return obj


def rng_state(rng: np.random.Generator) -> dict:
    """The full bit-generator state — JSON-able (Python ints are unbounded,
    so PCG64's 128-bit words survive a JSON round-trip exactly)."""
    return rng.bit_generator.state


def set_rng_state(rng: np.random.Generator, state: dict) -> None:
    rng.bit_generator.state = state


def _trace_state(trace) -> list:
    return [[float(t), _jsonify(c)] for t, c in trace]


def _trace_from_state(state) -> list:
    # int cost entries stay int through JSON, hetero float entries stay
    # float (json floats round-trip via repr) — the parity-pinned part of a
    # trace is its cost sequence; timestamps are wall-clock and exempt
    return [(t, c) for t, c in state]


def result_state(res: PackingResult) -> dict:
    return {
        "solution": res.solution.state_dict(),
        "cost": int(res.cost),
        "efficiency": float(res.efficiency),
        "wall_time_s": float(res.wall_time_s),
        "algorithm": res.algorithm,
        "trace": _trace_state(res.trace),
        "iterations": int(res.iterations),
        "params": _jsonify(res.params),
    }


def result_from_state(prob: PackingProblem, state: dict) -> PackingResult:
    return PackingResult(
        solution=Solution.from_state_dict(prob, state["solution"]),
        cost=int(state["cost"]),
        efficiency=float(state["efficiency"]),
        wall_time_s=float(state["wall_time_s"]),
        algorithm=state["algorithm"],
        trace=_trace_from_state(state["trace"]),
        iterations=int(state["iterations"]),
        params=state["params"],
    )


# ---------------------------------------------------------------- digests
def _digest(payload: str) -> str:
    return hashlib.blake2b(payload.encode(), digest_size=16).hexdigest()


def task_digest(key: tuple) -> str:
    """Stable id of one sweep candidate: problem fingerprint + algorithm +
    seed + settings (``dse._task_keys`` already folds all of those in)."""
    return _digest(repr(key))


def group_digest(keys: Sequence[tuple]) -> str:
    """Stable id of one batched group (order-independent membership)."""
    return _digest(repr(sorted(task_digest(k) for k in keys)))


def sweep_config_key(keys: Sequence[tuple]) -> str:
    """Identity of a whole sweep: the multiset of its task keys.  A resumed
    call must describe the same sweep; barrier spacing deliberately does
    not participate (any segmentation replays the same trajectories)."""
    return _digest(repr((FORMAT, "sweep", sorted(task_digest(k) for k in keys))))


def portfolio_config_key(
    prob, islands, interval, intra_layer, backend, sa_chains, hyper,
    race=None,
) -> str:
    """Identity of a portfolio run.  ``max_seconds`` is deliberately
    excluded: it is an outer safety cap, and resuming a preempted run with
    a fresh (or larger) wall budget is the expected workflow.  ``race``
    (the ``(race_budget, race_final)`` tuple of a ``pack_portfolio(auto=
    True)`` run, None otherwise) is part of the identity: a race resumed
    under a different ledger would reach different eliminations.  Non-race
    digests are unchanged from format 1."""
    spec = tuple(
        (s.algorithm, int(s.seed),
         tuple(sorted((k, repr(v)) for k, v in s.hyper.items())))
        for s in islands
    )
    key = (
        FORMAT, "portfolio", prob.fingerprint(), spec, int(interval),
        bool(intra_layer), backend, int(sa_chains),
        tuple(sorted((k, repr(v)) for k, v in hyper.items())),
    )
    if race is not None:
        key = key + (("race",) + tuple(race),)
    return _digest(repr(key))


# ----------------------------------------------------------- engine codecs
def encode_scalar_run(st) -> tuple[dict, dict]:
    """`sa._ScalarRun` -> (arrays, extra); everything is small, all JSON."""
    extra = {f: _jsonify(getattr(st, f)) for f in type(st).CODEC_SCALARS}
    for f in type(st).CODEC_SOLUTIONS:
        extra[f] = getattr(st, f).state_dict()
    extra["rng"] = rng_state(st.rng)
    extra["trace"] = _trace_state(st.trace)
    return {}, extra


def restore_scalar_run(st, extra: dict) -> None:
    """Overwrite a freshly `_scalar_start`-ed run with checkpointed state."""
    for f in type(st).CODEC_SCALARS:
        setattr(st, f, extra[f])
    st.sol = Solution.from_state_dict(st.prob, extra["sol"])
    st.best = Solution.from_state_dict(st.prob, extra["best"])
    set_rng_state(st.rng, extra["rng"])
    st.trace = _trace_from_state(extra["trace"])
    st.t_start = time.perf_counter()  # wall budget re-bases on resume


def encode_single_run(st) -> tuple[dict, dict]:
    """`sa._SingleChainRun` -> (arrays, extra); geometry rows and primitive
    usage are derived from ``sol`` on restore, not serialized."""
    return encode_scalar_run(st)  # identical layout; CODEC_* differ per class


def restore_single_run(st, extra: dict) -> None:
    restore_scalar_run(st, extra)
    st.sol.fill_geometry(st.chain_w[0], st.chain_h[0])
    if st.hetero:
        st.sol.fill_kinds(st.chain_k[0])
        st.used = st.sol.used_primitives()
    st.undo.clear()


def encode_block_state(st) -> tuple[dict, dict]:
    """`sa._BlockState` -> (arrays, extra) for one P x C fleet."""
    cls = type(st)
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if st.hetero else ())
    arrays = {f: np.asarray(getattr(st, f)) for f in fields}
    extra = {f: _jsonify(getattr(st, f)) for f in cls.CODEC_SCALARS}
    extra["hetero"] = bool(st.hetero)
    extra["n_rows"] = int(st.n_rows)
    extra["rngs"] = [rng_state(r) for r in st.rngs]
    extra["traces"] = [_trace_state(tr) for tr in st.traces]
    return arrays, extra


def restore_block_state(st, arrays: dict, extra: dict) -> None:
    """Overwrite a freshly `_block_start`-ed fleet with checkpointed state.

    The fresh state is the layout template: every restored array must match
    its shape exactly (same problems, same chain count — the config digest
    upstream should make a mismatch impossible; this is the backstop).
    """
    if bool(extra["hetero"]) != bool(st.hetero) or int(extra["n_rows"]) != st.n_rows:
        raise ValueError("checkpoint does not match this fleet's layout")
    cls = type(st)
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if st.hetero else ())
    for f in fields:
        cur = np.asarray(getattr(st, f))
        arr = np.asarray(arrays[f])
        if cur.shape != arr.shape or cur.dtype != arr.dtype:
            raise ValueError(
                f"checkpoint field {f!r}: {arr.shape}/{arr.dtype} does not "
                f"match fleet layout {cur.shape}/{cur.dtype}"
            )
        setattr(st, f, arr)
    if not st.hetero:
        st.pcosts = st.costs  # pcosts aliases costs on single-kind fleets
    for f in cls.CODEC_SCALARS:
        setattr(st, f, extra[f])
    for rng, state in zip(st.rngs, extra["rngs"]):
        set_rng_state(rng, state)
    st.traces = [_trace_from_state(tr) for tr in extra["traces"]]
    st.t_start = time.perf_counter()


# fields concatenated on the chain-row (R) axis; everything else in the
# block codec concatenates on the problem (P) axis
_ROW_FIELDS = frozenset({
    "items", "counts", "bw", "bh", "live", "costs", "best_pcosts",
    "stale", "steps", "pcosts", "bk", "UK",
})
# pad fill for widened trailing envelope dims (-1 = the empty-item sentinel
# of encode_chain_items; every other field pads with zeros)
_PAD_FILL = {"items": -1, "g_items": -1}


def _pad_tail(arr: np.ndarray, tail: tuple, fill) -> np.ndarray:
    """Widen an array's trailing dims to ``tail`` (leading axis untouched)."""
    shape = (arr.shape[0],) + tail
    if arr.shape == shape:
        return arr
    out = np.full(shape, fill, dtype=arr.dtype)
    out[tuple(slice(0, s) for s in arr.shape)] = arr
    return out


def merge_block_states(sts) -> tuple[dict, dict]:
    """Merge per-shard `_BlockState`s into ONE canonical (arrays, extra).

    The sharded sweep/portfolio lanes (docs/DESIGN.md section 14) split a
    batched group into contiguous sub-fleets, synchronized at common
    iteration barriers.  This merges their states into a payload laid out
    **exactly** like :func:`encode_block_state` of the equivalent unsharded
    fleet: shard envelopes pad to the group envelope (max bin-slot and
    item-capacity dims — trailing empty slots are trajectory-neutral,
    section 10), rows concatenate in group order, ``it`` is the barrier
    (the max — a shard that froze early stops counting, but frozen rows
    are immutable so the gap is inert), and ``done``/``frozen`` are the
    fleet-wide conjunctions.  A snapshot written at one shard count
    therefore restores at ANY other: `restore_block_state` consumes it
    unsharded, :func:`restore_block_shards` slices it back onto shards.
    """
    encoded = [encode_block_state(st) for st in sts]
    cls = type(sts[0])
    hetero = bool(sts[0].hetero)
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if hetero else ())
    arrays: dict = {}
    for f in fields:
        parts = [e[0][f] for e in encoded]
        tail = tuple(
            max(p.shape[d] for p in parts) for d in range(1, parts[0].ndim)
        )
        fill = _PAD_FILL.get(f, 0)
        arrays[f] = np.concatenate(
            [_pad_tail(p, tail, fill) for p in parts], axis=0
        )
    extra = {
        "it": max(int(e["it"]) for _, e in encoded),
        "done": all(bool(e["done"]) for _, e in encoded),
        "frozen": all(bool(e["frozen"]) for _, e in encoded),
        "hetero": hetero,
        "n_rows": sum(int(e["n_rows"]) for _, e in encoded),
        "rngs": [r for _, e in encoded for r in e["rngs"]],
        "traces": [t for _, e in encoded for t in e["traces"]],
    }
    return arrays, extra


def restore_block_shards(sts, arrays: dict, extra: dict, patience: int) -> None:
    """Slice one canonical fleet snapshot onto freshly-started shard states.

    The inverse of :func:`merge_block_states`, for any shard count: shard
    ``i`` gets the canonical payload's rows/problems at its contiguous
    offsets.  Shard envelopes may be narrower than the canonical one — the
    restored shard simply keeps the canonical (wider) arrays, since
    trailing empty bin slots never alter trajectories (DESIGN.md sections
    10/14).  Every shard restores ``it`` to the fleet barrier (frozen
    shards draw no RNG there, so the counter is inert); per-shard
    ``frozen``/``done`` are recomputed from the restored patience counters
    against ``patience`` (the packer's), because a sub-fleet freezes as a
    unit even when the full fleet was still live.
    """
    hetero = bool(extra["hetero"])
    if any(bool(st.hetero) != hetero for st in sts):
        raise ValueError("checkpoint does not match this fleet's layout")
    n_rows = int(extra["n_rows"])
    if n_rows != sum(st.n_rows for st in sts):
        raise ValueError(
            f"checkpoint holds {n_rows} chain rows but the shard split has "
            f"{sum(st.n_rows for st in sts)}; the group membership changed"
        )
    cls = type(sts[0])
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if hetero else ())
    n_probs = sum(st.n_probs for st in sts)
    rngs = extra["rngs"]
    traces = extra["traces"]
    if len(rngs) != n_probs or len(traces) != n_probs:
        raise ValueError("checkpoint problem count does not match")
    r0 = p0 = 0
    for st in sts:
        nr, npb = st.n_rows, st.n_probs
        for f in fields:
            arr = np.asarray(arrays[f])
            cur = np.asarray(getattr(st, f))
            if arr.dtype != cur.dtype or arr.ndim != cur.ndim:
                raise ValueError(
                    f"checkpoint field {f!r}: {arr.dtype}/{arr.ndim}d does "
                    f"not match fleet layout {cur.dtype}/{cur.ndim}d"
                )
            if any(a < c for a, c in zip(arr.shape[1:], cur.shape[1:])):
                raise ValueError(
                    f"checkpoint field {f!r}: envelope {arr.shape[1:]} is "
                    f"narrower than the shard's {cur.shape[1:]}"
                )
            lo, n = (r0, nr) if f in _ROW_FIELDS else (p0, npb)
            setattr(st, f, arr[lo:lo + n].copy())
        if not hetero:
            st.pcosts = st.costs  # pcosts aliases costs on single-kind fleets
        st.it = int(extra["it"])
        frozen = bool(np.all(np.asarray(st.stale) >= patience))
        st.frozen = frozen
        st.done = frozen or bool(extra["done"])
        for rng, state in zip(st.rngs, rngs[p0:p0 + npb]):
            set_rng_state(rng, state)
        st.traces = [_trace_from_state(tr) for tr in traces[p0:p0 + npb]]
        st.t_start = time.perf_counter()
        r0 += nr
        p0 += npb


def encode_ga_run(run) -> tuple[dict, dict]:
    """`ga._GARun` -> (arrays, extra)."""
    cls = type(run)
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if run.hetero else ())
    arrays = {f: np.asarray(getattr(run, f)) for f in fields}
    extra = {f: _jsonify(getattr(run, f)) for f in cls.CODEC_SCALARS}
    extra["hetero"] = bool(run.hetero)
    extra["rng"] = rng_state(run.rng)
    extra["pop"] = [s.state_dict() for s in run.pop]
    extra["best"] = run.best.state_dict()
    extra["trace"] = _trace_state(run.trace)
    return arrays, extra


def restore_ga_run(run, arrays: dict, extra: dict) -> None:
    """Overwrite a freshly started+evaluated `_GARun` with checkpointed
    state (the fresh run is the shape template; ``W``/``H``/``Km`` are
    refilled from the restored population)."""
    if bool(extra["hetero"]) != bool(run.hetero):
        raise ValueError("checkpoint does not match this run's problem")
    if len(extra["pop"]) != len(run.pop):
        raise ValueError("checkpoint population size does not match n_pop")
    cls = type(run)
    fields = cls.CODEC_ARRAYS + (cls.CODEC_ARRAYS_HETERO if run.hetero else ())
    for f in fields:
        cur = np.asarray(getattr(run, f))
        arr = np.asarray(arrays[f])
        if cur.shape != arr.shape:
            raise ValueError(f"checkpoint field {f!r} shape mismatch")
        setattr(run, f, arr)
    for f in cls.CODEC_SCALARS:
        setattr(run, f, extra[f])
    set_rng_state(run.rng, extra["rng"])
    run.pop = [Solution.from_state_dict(run.prob, d) for d in extra["pop"]]
    run.best = Solution.from_state_dict(run.prob, extra["best"])
    run.trace = _trace_from_state(extra["trace"])
    run.t0 = time.perf_counter()
    if run.batched:
        for i, s in enumerate(run.pop):
            s.fill_geometry(run.W[i], run.H[i])
            if run.Km is not None:
                s.fill_kinds(run.Km[i])


def encode_ga_group(runs) -> tuple[dict, list]:
    """A lockstep group of `_GARun`s -> (prefixed arrays, list of extras)."""
    arrays: dict = {}
    extras: list = []
    for i, run in enumerate(runs):
        a, e = encode_ga_run(run)
        for k, v in a.items():
            arrays[f"{i}/{k}"] = v
        extras.append(e)
    return arrays, extras


# ------------------------------------------------------------ checkpointers
class _Checkpointer:
    """Shared machinery: synchronous CheckpointManager IO, monotone step
    numbering, config validation, and the post-snapshot hook the
    fault-injection harness attaches to."""

    kind = ""

    def __init__(
        self,
        directory,
        config_key: str,
        every: int = 1,
        resume: bool = False,
        keep_n: int = 3,
        on_checkpoint: Callable[[int], None] | None = None,
    ):
        # synchronous saves: a barrier snapshot must be durable before the
        # run advances past it (the kill-at-barrier contract)
        self.mgr = CheckpointManager(
            directory, keep_n=max(int(keep_n), 2), async_save=False
        )
        self.every = max(int(every), 1)
        self.on_checkpoint = on_checkpoint
        self.config_key = config_key
        self.step = 0
        self.payload: dict | None = None
        self.flat: dict = {}
        if resume:
            try:
                step, flat, extra = self.mgr.restore_latest_valid()
            except FileNotFoundError:
                return  # nothing snapshotted yet: a fresh start
            if extra.get("format") != FORMAT or extra.get("kind") != self.kind:
                raise ValueError(
                    f"checkpoint under {self.mgr.dir} is not a {self.kind} "
                    f"checkpoint of format {FORMAT}"
                )
            if extra.get("config") != config_key:
                raise ValueError(
                    f"checkpoint under {self.mgr.dir} was written by a "
                    "differently-configured run (problems/seeds/settings "
                    "changed); refusing to resume"
                )
            self.step = step
            self.payload = extra
            self.flat = flat

    def _save(self, arrays: dict, payload: dict) -> None:
        self.step += 1
        extra = {"format": FORMAT, "kind": self.kind,
                 "config": self.config_key, **payload}
        self.mgr.save(self.step, arrays, extra)
        if self.on_checkpoint is not None:
            self.on_checkpoint(self.step)


class SweepCheckpointer(_Checkpointer):
    """Checkpoint/resume driver for :func:`repro.core.dse.pack_sweep`.

    Snapshot layout: completed-candidate results keyed by task digest in
    the JSON payload; the in-flight batched group's engine state (one
    `_BlockState`, or one `_GARun` per group member) as prefixed arrays +
    the ``engine`` payload, tagged with the group's membership digest so a
    resume only re-enters matching work.
    """

    kind = "sweep"

    def __init__(self, directory, config_key, every=256, resume=False,
                 keep_n=3, on_checkpoint=None):
        super().__init__(directory, config_key, every=every, resume=resume,
                         keep_n=keep_n, on_checkpoint=on_checkpoint)
        self.done: dict[str, dict] = {}
        self._group: str | None = None
        self._engine = None
        if self.payload is not None:
            self.done = dict(self.payload.get("done", {}))
            self._group = self.payload.get("group")
            self._engine = self.payload.get("engine")

    # ------------------------------------------------- completed candidates
    def result_for(self, key: tuple, prob: PackingProblem) -> PackingResult | None:
        state = self.done.get(task_digest(key))
        return None if state is None else result_from_state(prob, state)

    def mark_done(self, key: tuple, result: PackingResult) -> None:
        self.done[task_digest(key)] = result_state(result)

    # -------------------------------------------------- barrier snapshots
    def save_progress(self, group: str | None = None, arrays: dict | None = None,
                      engine=None) -> None:
        """One durable snapshot: all completed results + the in-flight
        group's engine state (none after a group completes)."""
        prefixed = {
            _ENGINE_PREFIX + k: v for k, v in (arrays or {}).items()
        }
        self._save(prefixed, {"done": self.done, "group": group,
                              "engine": engine})

    def _engine_arrays(self, prefix: str = "") -> dict:
        p = _ENGINE_PREFIX + prefix
        return {k[len(p):]: v for k, v in self.flat.items() if k.startswith(p)}

    def restore_block(self, gdigest: str, st) -> bool:
        """Re-enter a checkpointed SA fleet group; False when the snapshot
        holds no engine state for this group (fresh start)."""
        if self._group != gdigest or not isinstance(self._engine, dict):
            return False
        restore_block_state(st, self._engine_arrays(), self._engine)
        return True

    def restore_block_shards(self, gdigest: str, sts, patience: int) -> bool:
        """Shard-count-agnostic variant of :meth:`restore_block`: slice the
        canonical group snapshot onto any contiguous shard split (the
        snapshot itself is always written merged — see
        :func:`merge_block_states`)."""
        if self._group != gdigest or not isinstance(self._engine, dict):
            return False
        restore_block_shards(sts, self._engine_arrays(), self._engine,
                             patience)
        return True

    def restore_ga_group(self, gdigest: str, runs) -> bool:
        if self._group != gdigest or not isinstance(self._engine, list):
            return False
        if len(self._engine) != len(runs):
            raise ValueError("checkpoint group size does not match")
        for i, (run, extra) in enumerate(zip(runs, self._engine)):
            restore_ga_run(run, self._engine_arrays(f"{i}/"), extra)
        return True


class PortfolioCheckpointer(_Checkpointer):
    """Checkpoint/resume driver for :func:`repro.core.portfolio.pack_portfolio`.

    Snapshot layout: one entry per engine *group* (SA fleet / GA lockstep
    pack / scalar island) in construction order, plus the barrier and
    migration counters.  ``every`` counts migration barriers between
    snapshots.
    """

    kind = "portfolio"

    GROUP_TAGS = ("fleet", "ga", "scalar", "single")

    def save_groups(self, groups, barrier: int, migrations: int,
                    race: dict | None = None) -> None:
        """``race`` is the `_Race.state()` payload of a ``auto=True`` run
        (ledger counters + the elimination log), None for plain lineups —
        it rides the JSON payload so a preempted race resumes past its
        eliminations (the config key already pins the ledger identity)."""
        arrays, metas = self._encode_groups(groups)
        payload = {"barrier": int(barrier),
                   "migrations": int(migrations), "groups": metas}
        if race is not None:
            payload["race"] = race
        self._save(arrays, payload)

    @property
    def race(self) -> dict | None:
        """The snapshotted racing state, None when starting fresh or when
        the snapshot was cut by a non-racing run."""
        return None if self.payload is None else self.payload.get("race")

    def restore_groups(self, groups) -> tuple[int, int] | None:
        """Overwrite freshly built groups with the checkpointed states;
        returns (barrier, migrations), or None when starting fresh."""
        if self.payload is None:
            return None
        metas = self.payload.get("groups")
        if not isinstance(metas, list) or len(metas) != len(groups):
            raise ValueError("checkpoint does not match this portfolio's islands")
        from .portfolio import _GAGroup, _SAFleetGroup  # late: avoid cycle

        for gi, (group, meta) in enumerate(zip(groups, metas)):
            tag, state = meta["type"], meta["state"]
            if tag != self._group_tag(group):
                raise ValueError(
                    f"checkpoint group {gi} is {tag!r}, expected "
                    f"{self._group_tag(group)!r}"
                )
            if isinstance(group, _SAFleetGroup):
                # fleet snapshots use the canonical merged layout, so a run
                # may resume at a different shard count than it saved under
                restore_block_shards(
                    group.sts, self._group_arrays(gi), state,
                    group.packer.patience,
                )
            elif isinstance(group, _GAGroup):
                runs = [run for _, run in group.pairs]
                if len(state) != len(runs):
                    raise ValueError("checkpoint GA island count mismatch")
                for i, (run, extra) in enumerate(zip(runs, state)):
                    restore_ga_run(run, self._group_arrays(gi, f"{i}/"), extra)
            elif group.single:
                restore_single_run(group.st, state)
            else:
                restore_scalar_run(group.st, state)
        return int(self.payload["barrier"]), int(self.payload["migrations"])

    def _group_arrays(self, gi: int, prefix: str = "") -> dict:
        p = f"g{gi}/{prefix}"
        return {k[len(p):]: v for k, v in self.flat.items() if k.startswith(p)}

    @staticmethod
    def _group_tag(group) -> str:
        from .portfolio import _GAGroup, _SAFleetGroup  # late: avoid cycle

        if isinstance(group, _SAFleetGroup):
            return "fleet"
        if isinstance(group, _GAGroup):
            return "ga"
        return "single" if group.single else "scalar"

    def _encode_groups(self, groups) -> tuple[dict, list]:
        from .portfolio import _GAGroup, _SAFleetGroup  # late: avoid cycle

        arrays: dict = {}
        metas: list = []
        for gi, group in enumerate(groups):
            if isinstance(group, _SAFleetGroup):
                a, e = merge_block_states(group.sts)
                for k, v in a.items():
                    arrays[f"g{gi}/{k}"] = v
                metas.append({"type": "fleet", "state": e})
            elif isinstance(group, _GAGroup):
                a, e = encode_ga_group([run for _, run in group.pairs])
                for k, v in a.items():
                    arrays[f"g{gi}/{k}"] = v
                metas.append({"type": "ga", "state": e})
            else:  # _ScalarIsland: scalar loop or single-chain delta engine
                _, e = (
                    encode_single_run(group.st) if group.single
                    else encode_scalar_run(group.st)
                )
                metas.append(
                    {"type": "single" if group.single else "scalar", "state": e}
                )
        return arrays, metas
