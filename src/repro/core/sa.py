"""Simulated-annealing memory packer — Algorithm 3 of the paper.

SA-S reproduces Vasiljevic & Chow's MPack approach (buffer-swap
perturbation); SA-NFD replaces the perturbation with the paper's Next-Fit
Dynamic repack.  Temperature follows a Lundy-Mees schedule
``T = T0 / (1 + Rc * iter)`` parameterized by the paper's Table 2 (T0, Rc);
acceptance of uphill moves is Metropolis: ``P_A = exp(-dE / T)``.
"""
from __future__ import annotations

import math
import time

import numpy as np

from .ga import buffer_swap
from .nfd import nfd_from_scratch, nfd_repack
from .problem import PackingProblem, PackingResult, Solution


class SimulatedAnnealingPacker:
    def __init__(
        self,
        perturbation: str = "nfd",  # "nfd" (SA-NFD) or "swap" (SA-S)
        t0: float = 30.0,
        rc: float = 1.0,
        p_adm_w: float = 0.0,
        p_adm_h: float = 0.1,
        nfd_threshold: float = 0.95,
        nfd_extra_frac: float = 0.01,
        nfd_max_bins: int = 8,
        swap_moves: int = 2,
        intra_layer: bool = False,
        max_seconds: float = 60.0,
        max_iterations: int = 2_000_000,
        patience: int = 20_000,
        seed: int = 0,
    ):
        if perturbation not in ("nfd", "swap"):
            raise ValueError(f"unknown perturbation {perturbation!r}")
        self.__dict__.update(locals())
        del self.__dict__["self"]
        # warm state for portfolio restarts (set after each pack())
        self.last_solution_: Solution | None = None

    @property
    def name(self) -> str:
        return "SA-NFD" if self.perturbation == "nfd" else "SA-S"

    def _perturb(self, sol: Solution, rng: np.random.Generator) -> Solution:
        if self.perturbation == "nfd":
            return nfd_repack(
                sol,
                rng,
                threshold=self.nfd_threshold,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                extra_frac=self.nfd_extra_frac,
                max_bins=self.nfd_max_bins,
            )
        return buffer_swap(
            sol, rng, n_moves=self.swap_moves, intra_layer=self.intra_layer
        )

    def pack(self, prob: PackingProblem, init: Solution | None = None) -> PackingResult:
        """Anneal from scratch, or warm-start from ``init`` (island restarts)."""
        rng = np.random.default_rng(self.seed)
        t_start = time.perf_counter()
        sol = init.copy() if init is not None else nfd_from_scratch(
            prob,
            rng,
            p_adm_w=self.p_adm_w,
            p_adm_h=self.p_adm_h,
            intra_layer=self.intra_layer,
        )
        cost = sol.cost()
        best, best_cost = sol.copy(), cost
        trace = [(time.perf_counter() - t_start, best_cost)]
        it = 0
        stale = 0
        while it < self.max_iterations and stale < self.patience:
            if (it & 0xFF) == 0 and time.perf_counter() - t_start > self.max_seconds:
                break
            temp = self.t0 / (1.0 + self.rc * it)
            cand = self._perturb(sol, rng)
            cand_cost = cand.cost()
            d_e = cand_cost - cost
            if d_e < 0 or (temp > 0 and rng.random() < math.exp(-d_e / temp)):
                sol, cost = cand, cand_cost
            if cost < best_cost:
                best, best_cost = sol.copy(), cost
                trace.append((time.perf_counter() - t_start, best_cost))
                stale = 0
            else:
                stale += 1
            it += 1
        wall = time.perf_counter() - t_start
        trace.append((wall, best_cost))
        self.last_solution_ = sol
        return PackingResult(
            solution=best,
            cost=int(best_cost),
            efficiency=best.efficiency(),
            wall_time_s=wall,
            algorithm=self.name + ("-intra" if self.intra_layer else ""),
            trace=trace,
            iterations=it,
            params=dict(
                t0=self.t0,
                rc=self.rc,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                seed=self.seed,
            ),
        )
