"""Simulated-annealing memory packer — Algorithm 3 of the paper, scaled out.

SA-S reproduces Vasiljevic & Chow's MPack approach (buffer-swap
perturbation); SA-NFD replaces the perturbation with the paper's Next-Fit
Dynamic repack.  Temperature follows a Lundy-Mees schedule
``T = T0 / (1 + Rc * iter)`` parameterized by the paper's Table 2 (T0, Rc);
acceptance of uphill moves is Metropolis: ``P_A = exp(-dE / T)``.

Three engines share this class:

* The **scalar loop** (``backend="legacy"``, and always for the NFD
  perturbation, whose repack is inherently sequential Python): one chain,
  one full ``Solution`` copy per proposed move — the seed implementation,
  kept verbatim as the benchmark baseline.
* The **single-chain delta engine** (``n_chains=1``, swap perturbation,
  backends ``auto/python/ref/pallas``): moves are applied to the incumbent
  *in place* with an undo log instead of copying, and only the touched
  bins' before/after geometry goes through the fused
  ``kernels.binpack_sa_step`` delta-cost kernel.  This engine consumes its
  ``np.random.Generator`` in exactly the scalar loop's order (per-move
  scalar draws; the Metropolis uniform drawn only for uphill moves) and
  compares against float64 ``math.exp`` — so every backend, including
  ``legacy``, produces the same trajectory for the same seed (pinned in
  ``tests/test_engine.py``).  Delta costs are exact integers in every
  backend, so the kernel choice can never fork a trajectory.
* The **vectorized multi-chain engine** (``n_chains=C > 1``): chain state
  is encoded once into padded ``(C, NB, max_items)`` item matrices plus
  ``(C, NB)`` geometry matrices (the codecs in ``core.problem``), and the
  whole step — move generation from one ``(n_moves, 4, C)`` uniform block,
  move application, delta-cost evaluation, Metropolis acceptance, and
  rollback of rejected chains — runs as numpy array programs over all
  chains at once, with zero per-chain Python in the loop.  Chains form a
  *temperature ladder* (chain 0 at the paper's T0, the rest log-spaced over
  ``[T0*ladder_min, T0*ladder_max]``); every ``exchange_every`` steps the
  worst chain adopts the global best state (best-chain exchange, the cheap
  cousin of parallel-tempering configuration swaps) and emptied bins are
  compacted out of the live slot window.  Within-bin slot order differs
  from the scalar loop's list order (array removal swaps with the last
  slot), so multi-chain runs define their own — still backend-identical —
  trajectories.  The engine is implemented as a *fleet* core
  (`_anneal_block`): P problems x C chains advance as one problem-major
  ``(P*C, ...)`` array program with per-problem RNG streams, temperature
  ladders, best tracking, and early-exit freezing — ``core.dse.pack_sweep``
  batches whole DSE candidate fleets through it, and a single-problem run
  is literally ``P == 1`` (docs/DESIGN.md section 10).

Every engine is **resumable**: the loop state lives in a run object
(`_BlockState` / `_ScalarRun` / `_SingleChainRun`) created by a ``_start``
helper, advanced by a ``_run`` helper that accepts an iteration *barrier*
(``it_limit``), and closed by a ``_finish`` helper.  The public ``pack()``
entry points simply compose start + run-to-budget + finish, so they are
bit-identical to the historical monolithic loops; ``core.portfolio`` drives
the same helpers in iteration-budgeted segments, pausing every island at
deterministic barriers for migration (the ``_*_migrate`` hooks), which is
what makes portfolio runs machine-speed-independent (docs/DESIGN.md
section 11).

On heterogeneous OCM problems every engine anneals the inventory-penalized
cost: with probability ``p_kind`` a move is a RAM-kind flip of a random bin
(scalar loop + single-chain engine share the draw inside
``apply_swap_moves``; the multi-chain engine widens its uniform block from
4 to 6 rows), the delta step routes per-slot kind lanes through the
per-kind mode tables of ``binpack_sa_step``, and the penalty delta comes
from exact per-kind primitive bookkeeping — so scalar/delta parity and
multi-chain backend parity both extend to the heterogeneous model.
Single-kind problems take none of these branches and stay bit-identical to
PR 2.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import numpy as np

from .ga import (
    BACKENDS,
    _default_jax_backend,
    apply_swap_moves,
    buffer_swap,
    kind_reassign,
    undo_swap_moves,
)
from .nfd import nfd_from_scratch, nfd_repack
from .problem import (
    DEFAULT_INVENTORY_PENALTY,
    PackingProblem,
    PackingResult,
    Solution,
    decode_chain_items,
    encode_chain_geometry,
    encode_chain_items,
    encode_chain_kinds,
    encode_problem_batch,
)


@dataclasses.dataclass
class _BlockOut:
    """Per-problem outcome of one `_anneal_block` fleet run."""

    best: Solution
    best_cost: int
    trace: list
    iterations: int
    chains: list
    incumbent: int  # index of the chain holding the best incumbent state
    uphill: tuple[int, int]
    wall: float


class _BlockState:
    """Resumable state of one `_anneal_block` fleet (P problems x C chains).

    Built by `_block_start`, advanced by `_block_run` (optionally only up
    to an iteration barrier), decoded by `_block_finish`.  All chain/geometry
    matrices, per-problem RNG streams, best tracking, and patience counters
    live here, so pausing at a barrier and resuming is bit-identical to one
    uninterrupted run — the contract the fleet-native portfolio builds on.

    ``CODEC_*`` is the serialization contract consumed by ``core.resume``:
    array fields land in a checkpoint's ``arrays.npz``, scalar fields (plus
    RNG bit-generator states and traces, handled explicitly by the codec)
    in its JSON manifest.  Everything else — scratch buffers refilled every
    step (``tslots``/``entry_ok``/``u_all``/``u_metro``), start-derived
    constants (tables, ladders, row maps), and the problems themselves — is
    rebuilt deterministically by `_block_start` and never serialized.
    """

    done: bool = False      # budget/wall exhausted or every problem frozen
    frozen: bool = False    # every problem past patience (subset of done)

    CODEC_ARRAYS = (
        "items", "counts", "bw", "bh", "live", "costs", "best_pcosts",
        "stale", "steps", "gbest_pcost", "gbest_cost", "g_items",
        "g_counts", "g_live", "up_prop", "up_acc",
    )
    CODEC_ARRAYS_HETERO = ("pcosts", "bk", "UK", "g_kinds", "g_UK")
    CODEC_SCALARS = ("it", "done", "frozen")


class _ScalarRun:
    """Resumable state of the scalar SA loop (one chain, Solution copies).

    ``CODEC_*``: the ``core.resume`` serialization contract (see
    `_BlockState`); ``sol``/``best`` serialize as bins + kind lanes, with
    geometry caches rebuilt cold on restore.
    """

    done: bool = False

    CODEC_SCALARS = ("cost", "ovf", "best_cost", "best_ovf", "it", "stale",
                     "done")
    CODEC_SOLUTIONS = ("sol", "best")


class _SingleChainRun:
    """Resumable state of the single-chain delta engine.

    ``CODEC_*``: the ``core.resume`` serialization contract (see
    `_BlockState`).  The geometry rows (``chain_w``/``chain_h``/``chain_k``)
    and primitive usage (``used``) are derived from ``sol`` on restore; the
    ``undo`` log and delta scratch rows are per-iteration transients, and
    barriers always fall between iterations.
    """

    done: bool = False

    CODEC_SCALARS = ("cost", "ovf", "best_cost", "best_ovf", "uphill_prop",
                     "uphill_acc", "it", "stale", "done")
    CODEC_SOLUTIONS = ("sol", "best")


class SimulatedAnnealingPacker:
    def __init__(
        self,
        perturbation: str = "nfd",  # "nfd" (SA-NFD) or "swap" (SA-S)
        t0: float = 30.0,
        rc: float = 1.0,
        p_adm_w: float = 0.0,
        p_adm_h: float = 0.1,
        nfd_threshold: float = 0.95,
        nfd_extra_frac: float = 0.01,
        nfd_max_bins: int = 8,
        swap_moves: int = 2,
        intra_layer: bool = False,
        max_seconds: float = 60.0,
        max_iterations: int = 2_000_000,
        patience: int = 20_000,
        seed: int = 0,
        n_chains: int = 1,
        backend: str = "auto",
        exchange_every: int = 256,
        ladder_min: float = 0.25,
        ladder_max: float = 4.0,
        p_kind: float = 0.15,
        inventory_penalty: float = DEFAULT_INVENTORY_PENALTY,
    ):
        if perturbation not in ("nfd", "swap"):
            raise ValueError(f"unknown perturbation {perturbation!r}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
        if n_chains < 1:
            raise ValueError("n_chains must be >= 1")
        self.__dict__.update(locals())
        del self.__dict__["self"]
        # warm state for portfolio restarts (set after each pack())
        self.last_solution_: Solution | None = None
        self.last_chains_: list[Solution] | None = None
        self._hetero = False  # set per problem in pack()

    @property
    def name(self) -> str:
        base = "SA-NFD" if self.perturbation == "nfd" else "SA-S"
        if self.perturbation == "swap" and self.n_chains > 1:
            base += f"x{self.n_chains}"
        return base

    def _resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        # unlike the GA (auto -> ref on CPU), SA steps are tiny (C x 2 x
        # swap_moves entries): host numpy beats per-step device dispatch
        from repro.kernels.binpack_sa_step.ops import resolve_auto

        return resolve_auto()[0]

    def _perturb(self, sol: Solution, rng: np.random.Generator) -> Solution:
        if self.perturbation == "nfd":
            # heterogeneous OCM: a fraction of NFD perturbations reassign RAM
            # kinds instead (no RNG draw at all on single-kind problems)
            if self._hetero and rng.random() < self.p_kind:
                return kind_reassign(sol, rng)
            return nfd_repack(
                sol,
                rng,
                threshold=self.nfd_threshold,
                p_adm_w=self.p_adm_w,
                p_adm_h=self.p_adm_h,
                intra_layer=self.intra_layer,
                extra_frac=self.nfd_extra_frac,
                max_bins=self.nfd_max_bins,
            )
        return buffer_swap(
            sol, rng, n_moves=self.swap_moves, intra_layer=self.intra_layer,
            p_kind=self.p_kind if self._hetero else 0.0,
        )

    def pack(
        self,
        prob: PackingProblem,
        init: Solution | Sequence[Solution] | None = None,
    ) -> PackingResult:
        """Anneal from scratch, or warm-start from ``init`` (island restarts).

        ``init`` may be a single solution or a per-chain list (extra chains
        start from fresh NFD packings).  The NFD perturbation always runs
        the scalar loop (its repack is sequential Python); for the swap
        perturbation the backend selects the engine, ``legacy`` being the
        scalar loop.
        """
        self._hetero = prob.n_kinds > 1
        if self.perturbation == "nfd" or self._resolve_backend() == "legacy":
            return self._pack_scalar(prob, init)
        if self.n_chains == 1:
            return self._pack_single_chain(prob, init, self._resolve_backend())
        return self._pack_multi_chain(prob, init, self._resolve_backend())

    # ------------------------------------------------------------ scalar loop
    def _pack_scalar(self, prob: PackingProblem, init) -> PackingResult:
        """The seed's serial annealer (one chain, one Solution copy per move)."""
        st = self._scalar_start(prob, init)
        self._scalar_run(st)
        return self._scalar_finish(st)

    def _scalar_start(
        self, prob: PackingProblem, init, rng: np.random.Generator | None = None
    ) -> _ScalarRun:
        if init is not None and not isinstance(init, Solution):
            init = init[0] if len(init) else None
        st = _ScalarRun()
        st.prob = prob
        st.rng = rng if rng is not None else np.random.default_rng(self.seed)
        st.t_start = time.perf_counter()
        sol = init.copy() if init is not None else nfd_from_scratch(
            prob,
            st.rng,
            p_adm_w=self.p_adm_w,
            p_adm_h=self.p_adm_h,
            intra_layer=self.intra_layer,
        )
        st.hetero = self._hetero
        st.lam = self.inventory_penalty
        st.sol = sol
        st.cost = sol.cost()
        st.ovf = sol.inventory_overflow() if st.hetero else 0
        st.best, st.best_cost, st.best_ovf = sol.copy(), st.cost, st.ovf
        # hetero traces record the penalized cost (the annealed quantity) so
        # the curve stays monotone; raw == penalized on single-kind problems
        st.trace = [(time.perf_counter() - st.t_start,
                     st.best_cost + st.lam * st.best_ovf if st.hetero
                     else st.best_cost)]
        st.it = 0
        st.stale = 0
        st.done = False
        return st

    def _scalar_run(self, st: _ScalarRun, it_limit: int | None = None) -> None:
        """Advance until ``it_limit`` (a portfolio barrier), the iteration /
        patience budget, or the wall cap; pausing at a barrier and resuming
        is bit-identical to one uninterrupted run."""
        limit = (
            self.max_iterations if it_limit is None
            else min(self.max_iterations, it_limit)
        )
        hetero, lam, rng = st.hetero, st.lam, st.rng
        while st.it < limit and st.stale < self.patience:
            if (st.it & 0xFF) == 0 and (
                time.perf_counter() - st.t_start > self.max_seconds
            ):
                st.done = True
                return
            temp = self.t0 / (1.0 + self.rc * st.it)
            cand = self._perturb(st.sol, rng)
            cand_cost = cand.cost()
            # the annealed energy is the inventory-penalized cost; the two
            # int deltas are kept separate so the single-kind path stays in
            # exact integer arithmetic (d_e is then just the cost delta)
            d_e = cand_cost - st.cost
            if hetero:
                cand_ovf = cand.inventory_overflow()
                d_e = d_e + lam * (cand_ovf - st.ovf)
            else:
                cand_ovf = 0
            if d_e < 0 or (temp > 0 and rng.random() < math.exp(-d_e / temp)):
                st.sol, st.cost, st.ovf = cand, cand_cost, cand_ovf
            if hetero:
                improved = (st.cost - st.best_cost) + lam * (st.ovf - st.best_ovf) < 0
            else:
                improved = st.cost < st.best_cost
            if improved:
                st.best, st.best_cost, st.best_ovf = st.sol.copy(), st.cost, st.ovf
                st.trace.append((time.perf_counter() - st.t_start,
                                 st.best_cost + lam * st.best_ovf if hetero
                                 else st.best_cost))
                st.stale = 0
            else:
                st.stale += 1
            st.it += 1
        if st.it >= self.max_iterations or st.stale >= self.patience:
            st.done = True

    def _scalar_finish(self, st: _ScalarRun) -> PackingResult:
        # the trace holds the monotone improvement curve only; the run's end
        # lives in wall_time_s (the seed appended a duplicate terminal tuple)
        wall = time.perf_counter() - st.t_start
        self.last_solution_ = st.sol
        self.last_chains_ = [st.sol]
        return self._result(
            st.best, int(st.best_cost), wall, st.trace, st.it, "legacy",
            uphill=None,
        )

    def _scalar_migrate(self, st: _ScalarRun, sol: Solution) -> bool:
        """Portfolio barrier hook: the migrant replaces the incumbent iff it
        strictly beats its penalized cost.  A finished run is never touched
        and ``stale`` is never reset, so migration cannot revive a frozen
        island (it stops drawing RNG exactly where a standalone run would).
        """
        if st.done or st.stale >= self.patience:
            return False
        lam = self.inventory_penalty
        cost = sol.cost()
        ovf = sol.inventory_overflow() if st.hetero else 0
        if cost + lam * ovf >= st.cost + lam * st.ovf:
            return False
        st.sol = sol.copy()
        st.cost = cost
        st.ovf = ovf
        # fold the migrant into the patience-reference best (no trace entry,
        # no stale reset): otherwise the next improved-check would treat the
        # migrant as this island's own discovery and revive its patience —
        # the same suppression `_block_migrate` does via best_pcosts
        if cost + lam * ovf < st.best_cost + lam * st.best_ovf:
            st.best, st.best_cost, st.best_ovf = st.sol.copy(), cost, ovf
        return True

    # ----------------------------------------------- single-chain delta engine
    def _pack_single_chain(self, prob: PackingProblem, init, backend):
        """One chain, in-place moves + undo, fused delta-cost evaluation.

        Bit-identical to the scalar loop for the same seed: same RNG stream
        (scalar per-move draws, Metropolis uniform only on uphill moves),
        same float64 ``math.exp`` compare, exact integer deltas.
        """
        st = self._single_start(prob, init, backend)
        self._single_run(st)
        return self._single_finish(st)

    def _single_start(
        self, prob: PackingProblem, init, backend,
        rng: np.random.Generator | None = None,
    ) -> _SingleChainRun:
        st = _SingleChainRun()
        st.prob = prob
        st.backend = backend
        st.interpret = backend == "pallas" and _default_jax_backend() != "tpu"
        st.rng = rng if rng is not None else np.random.default_rng(self.seed)
        st.t_start = time.perf_counter()
        if init is not None and not isinstance(init, Solution):
            init = init[0] if len(init) else None
        sol = init.copy() if init is not None else nfd_from_scratch(
            prob,
            st.rng,
            p_adm_w=self.p_adm_w,
            p_adm_h=self.p_adm_h,
            intra_layer=self.intra_layer,
        )
        st.sol = sol
        st.hetero = self._hetero
        st.lam = self.inventory_penalty
        st.pk = self.p_kind if st.hetero else 0.0
        st.kt = prob.kind_tables if st.hetero else None
        st.modes0 = prob.kind_tables[0][1]  # == BRAM18_MODES on default problems
        st.cost = int(sol.cost())
        st.chain_w = np.zeros((1, prob.n), dtype=np.int32)
        st.chain_h = np.zeros_like(st.chain_w)
        sol.fill_geometry(st.chain_w[0], st.chain_h[0])
        if st.hetero:
            st.chain_k = np.zeros((1, prob.n), dtype=np.int32)
            sol.fill_kinds(st.chain_k[0])
            st.used = sol.used_primitives()
            st.ovf = int(prob.overflow_units(st.used))
        else:
            st.chain_k = None
            st.used = None
            st.ovf = 0
        st.best, st.best_cost, st.best_ovf = sol.copy(), st.cost, st.ovf
        st.trace = [(time.perf_counter() - st.t_start,
                     st.best_cost + st.lam * st.best_ovf if st.hetero
                     else st.best_cost)]
        width = 2 * max(self.swap_moves, 1)
        st.old_w = np.zeros((1, width), dtype=np.int32)
        st.old_h = np.zeros_like(st.old_w)
        st.new_w = np.zeros_like(st.old_w)
        st.new_h = np.zeros_like(st.old_w)
        st.old_k = np.zeros_like(st.old_w) if st.hetero else None
        st.new_k = np.zeros_like(st.old_w) if st.hetero else None
        st.undo = []
        st.uphill_prop = 0
        st.uphill_acc = 0
        st.it = 0
        st.stale = 0
        st.done = False
        return st

    def _single_run(self, st: _SingleChainRun, it_limit: int | None = None) -> None:
        from repro.kernels.binpack_sa_step.ops import sa_step_deltas

        limit = (
            self.max_iterations if it_limit is None
            else min(self.max_iterations, it_limit)
        )
        prob, sol, rng = st.prob, st.sol, st.rng
        hetero, lam, pk, kt, modes0 = st.hetero, st.lam, st.pk, st.kt, st.modes0
        backend, interpret = st.backend, st.interpret
        chain_w, chain_h, chain_k = st.chain_w, st.chain_h, st.chain_k
        old_w, old_h = st.old_w, st.old_h
        new_w, new_h = st.new_w, st.new_h
        old_k, new_k = st.old_k, st.new_k
        undo = st.undo
        while st.it < limit and st.stale < self.patience:
            if (st.it & 0xFF) == 0 and (
                time.perf_counter() - st.t_start > self.max_seconds
            ):
                st.done = True
                return
            temp = self.t0 / (1.0 + self.rc * st.it)
            # --- propose in place (legacy RNG stream; kind moves only when
            # the problem is heterogeneous, matching the scalar loop)
            undo.clear()
            tset: set[int] = set()
            apply_swap_moves(
                sol, rng, n_moves=self.swap_moves,
                intra_layer=self.intra_layer, undo=undo, touched=tset,
                p_kind=pk,
            )
            tl = sorted(tset)
            k = len(tl)
            old_w[0] = 0
            old_h[0] = 0
            new_w[0] = 0
            new_h[0] = 0
            if k:
                old_w[0, :k] = chain_w[0, tl]
                old_h[0, :k] = chain_h[0, tl]
                ws, hs = sol.scan_bin_geometry(tl)
                new_w[0, :k] = ws
                new_h[0, :k] = hs
            if hetero:
                old_k[0] = 0
                new_k[0] = 0
                if k:
                    old_k[0, :k] = chain_k[0, tl]
                    new_k[0, :k] = sol.kinds[tl]
                d_cost = int(
                    sa_step_deltas(
                        old_w, old_h, new_w, new_h, backend=backend,
                        interpret=interpret, old_k=old_k, new_k=new_k,
                        kind_tables=kt,
                    )[0]
                )
                # inventory-penalty delta from the touched bins' primitive
                # usage (exact integer bookkeeping, O(touched) cache hits)
                if prob._any_bounded:
                    used2 = st.used.copy()
                    for t in range(k):
                        if old_w[0, t] > 0:
                            used2[old_k[0, t]] -= prob.bin_primitives(
                                int(old_w[0, t]), int(old_h[0, t]), int(old_k[0, t])
                            )
                        if new_w[0, t] > 0:
                            used2[new_k[0, t]] += prob.bin_primitives(
                                int(new_w[0, t]), int(new_h[0, t]), int(new_k[0, t])
                            )
                    ovf2 = int(prob.overflow_units(used2))
                else:
                    used2, ovf2 = st.used, 0  # unbounded inventory never overflows
                d_e = d_cost + lam * (ovf2 - st.ovf)
            else:
                d_cost = int(
                    sa_step_deltas(
                        old_w, old_h, new_w, new_h, modes=modes0,
                        backend=backend, interpret=interpret,
                    )[0]
                )
                d_e = d_cost
            # --- Metropolis: the uniform is drawn only for uphill moves
            if d_e > 0:
                st.uphill_prop += 1
            if d_e < 0 or (temp > 0 and rng.random() < math.exp(-d_e / temp)):
                if d_e > 0:
                    st.uphill_acc += 1
                st.cost += d_cost
                if hetero:
                    st.used, st.ovf = used2, ovf2
                if tl:
                    sol.touch(*tl)
                    bins = sol.bins
                    if any(not bins[b] for b in tl):
                        sol.drop_empty()
                        sol.fill_geometry(chain_w[0], chain_h[0])
                        if hetero:
                            sol.fill_kinds(chain_k[0])
                    else:
                        chain_w[0, tl] = new_w[0, :k]
                        chain_h[0, tl] = new_h[0, :k]
                        if hetero:
                            chain_k[0, tl] = new_k[0, :k]
            else:
                undo_swap_moves(sol, undo)
            if hetero:
                improved = (st.cost - st.best_cost) + lam * (st.ovf - st.best_ovf) < 0
            else:
                improved = st.cost < st.best_cost
            if improved:
                st.best, st.best_cost, st.best_ovf = sol.copy(), st.cost, st.ovf
                st.trace.append((time.perf_counter() - st.t_start,
                                 st.best_cost + lam * st.best_ovf if hetero
                                 else st.best_cost))
                st.stale = 0
            else:
                st.stale += 1
            st.it += 1
        if st.it >= self.max_iterations or st.stale >= self.patience:
            st.done = True

    def _single_finish(self, st: _SingleChainRun) -> PackingResult:
        wall = time.perf_counter() - st.t_start
        self.last_solution_ = st.sol
        self.last_chains_ = [st.sol]
        return self._result(
            st.best, st.best_cost, wall, st.trace, st.it, st.backend,
            uphill=(st.uphill_prop, st.uphill_acc),
        )

    def _single_migrate(self, st: _SingleChainRun, sol: Solution) -> bool:
        """Portfolio barrier hook for the single-chain engine; same contract
        as `_scalar_migrate` (strictly-better only, frozen never revived)."""
        if st.done or st.stale >= self.patience:
            return False
        lam = self.inventory_penalty
        cost = int(sol.cost())
        ovf = int(sol.inventory_overflow()) if st.hetero else 0
        if cost + lam * ovf >= st.cost + lam * st.ovf:
            return False
        st.sol = sol.copy()
        st.cost = cost
        st.sol.fill_geometry(st.chain_w[0], st.chain_h[0])
        if st.hetero:
            st.sol.fill_kinds(st.chain_k[0])
            st.used = st.sol.used_primitives()
            st.ovf = int(st.prob.overflow_units(st.used))
        # patience-reference best absorbs the migrant (see _scalar_migrate)
        if cost + lam * st.ovf < st.best_cost + lam * st.best_ovf:
            st.best, st.best_cost, st.best_ovf = st.sol.copy(), cost, st.ovf
        return True

    # -------------------------------------------- vectorized multi-chain engine
    def _chain_t0s(self) -> np.ndarray:
        """Lundy-Mees T0 ladder: chain 0 at the configured T0 (single-chain
        parity), the rest log-spaced over [T0*ladder_min, T0*ladder_max]
        (a lone extra chain sits at the range's geometric mean)."""
        t0s = np.full(self.n_chains, float(self.t0))
        if self.n_chains == 2:
            t0s[1] = self.t0 * math.sqrt(self.ladder_min * self.ladder_max)
        elif self.n_chains > 2:
            t0s[1:] = self.t0 * np.geomspace(
                self.ladder_min, self.ladder_max, self.n_chains - 1
            )
        return t0s

    def _pack_multi_chain(self, prob, init, backend):
        """C temperature-laddered chains advanced in lock-step, all-numpy.

        A thin wrapper over the fleet engine `_anneal_block`: one problem,
        one RNG stream — the single-problem engine is literally ``P == 1``.
        """
        if init is None:
            inits: list[Solution] = []
        elif isinstance(init, Solution):
            inits = [init]
        else:
            inits = [s for s in init if s is not None][: self.n_chains]
        rng = np.random.default_rng(self.seed)
        out = self._anneal_block([prob], [rng], [inits], backend)[0]
        self.last_solution_ = out.chains[out.incumbent]
        self.last_chains_ = out.chains
        return self._result(
            out.best, out.best_cost, out.wall, out.trace, out.iterations,
            backend, uphill=out.uphill,
        )

    def _anneal_block(
        self,
        probs: Sequence[PackingProblem],
        rngs: Sequence[np.random.Generator],
        inits: Sequence[Sequence[Solution]],
        backend: str,
        mesh=None,
    ) -> list[_BlockOut]:
        """The vectorized annealer over a *fleet*: P problems x C chains.

        Every state matrix is laid out problem-major: row ``j * C + c`` is
        chain ``c`` of problem ``j``, padded to the fleet's common
        ``(NB, cap_max)`` envelope (`encode_problem_batch`).  Each problem
        consumes only its own ``rngs[j]`` stream — chain init first, then
        one uniform block plus one Metropolis block per step while the
        problem is live — so each problem's trajectory is bit-identical to
        a standalone ``n_chains=C`` run seeded the same way (pinned by
        ``tests/test_dse.py``), and the single-problem engine is literally
        ``P == 1``.  A problem *freezes* (stops drawing RNG, stops moving)
        once every one of its chains exceeds ``patience``; the loop exits
        when all problems are frozen or the shared iteration/wall budget
        runs out.  Per-problem temperature ladders, best tracking, traces,
        and best-chain exchange stay independent; the delta-cost kernel and
        Metropolis rule run once over all ``P * C`` rows per step.  See
        docs/DESIGN.md section 10.

        Implemented as `_block_start` + `_block_run` + `_block_finish`;
        ``core.portfolio`` replicates one problem K times through the same
        helpers and pauses `_block_run` at migration barriers.
        """
        st = self._block_start(probs, rngs, inits, backend, mesh=mesh)
        self._block_run(st)
        return self._block_finish(st)

    def _block_start(
        self,
        probs: Sequence[PackingProblem],
        rngs: Sequence[np.random.Generator],
        inits: Sequence[Sequence[Solution]],
        backend: str,
        n_slots: int | None = None,
        mesh=None,
    ) -> _BlockState:
        """Encode a fleet's chain state; ``n_slots`` widens the bin-slot
        envelope (the portfolio passes ``prob.n`` so any migrant fits —
        envelope padding never affects trajectories, see DESIGN.md §10).
        ``mesh`` (a ``("prob",)`` sweep mesh) row-shards the delta kernel on
        jax backends — a start-derived constant, never serialized (resume
        may restore onto a different mesh/shard count, DESIGN.md §14)."""
        st = _BlockState()
        st.mesh = mesh if backend in ("ref", "pallas") else None
        n_probs = st.n_probs = len(probs)
        n_chains = self.n_chains
        n_rows = st.n_rows = n_probs * n_chains
        st.n_moves = max(self.swap_moves, 1)
        width = 2 * st.n_moves
        st.probs = list(probs)
        st.rngs = list(rngs)
        st.backend = backend
        st.interpret = backend == "pallas" and _default_jax_backend() != "tpu"
        batch = st.batch = encode_problem_batch(probs)
        hetero = st.hetero = batch.n_kinds > 1
        lam = self.inventory_penalty
        st.kt = batch.kind_tables if hetero else None
        st.modes0 = batch.kind_tables[0][1]  # == BRAM18_MODES on defaults
        st.n_kinds = batch.n_kinds
        st.cap_max = batch.cap_max
        st.any_bounded = bool((batch.kind_counts >= 0).any())
        st.t_start = time.perf_counter()

        # --- per-problem chain init: warm starts first, fresh NFD for the rest
        sols: list[Solution] = []
        for j, prob in enumerate(probs):
            mine = [s.copy() for s in inits[j][:n_chains]]
            mine += [
                nfd_from_scratch(
                    prob,
                    rngs[j],
                    p_adm_w=self.p_adm_w,
                    p_adm_h=self.p_adm_h,
                    intra_layer=self.intra_layer,
                    sort_by_width=(c % 2 == 1),
                )
                for c in range(len(mine), n_chains)
            ]
            sols.extend(mine)
        st.items, st.counts = encode_chain_items(sols, st.cap_max, n_slots=n_slots)
        st.bw, st.bh, st.live = encode_chain_geometry(sols, st.items.shape[1])
        st.costs = np.asarray([s.cost() for s in sols], dtype=np.int64)

        st.pi = np.repeat(np.arange(n_probs), n_chains)  # row -> problem index
        st.caps_r = np.repeat(batch.max_items, n_chains)  # per-row cardinality
        # buffer lookup tables with a zero/empty sentinel in the last column;
        # a single-problem fleet keeps the flat 1-D tables (PR 2's hot path)
        wext, dext, lext = batch.ext_tables()
        if n_probs == 1:
            st.wtab, st.dtab, st.ltab = wext[0], dext[0], lext[0]
        else:
            st.wtab, st.dtab, st.ltab = wext, dext, lext
        st.sentinel = st.wtab.shape[-1] - 1

        if hetero:
            # per-chain RAM-kind lane + per-kind primitive usage (R, K)
            st.bk = encode_chain_kinds(sols, st.items.shape[1])
            st.UK = np.stack([s.used_primitives() for s in sols])
            st.pcosts = st.costs + lam * batch.overflow_rows(st.UK, st.pi)
        else:
            st.bk = None
            st.UK = None
            st.pcosts = st.costs

        st.best_pcosts = st.pcosts.copy()  # per-chain best (drives patience)
        st.poff = np.arange(n_probs) * n_chains
        gis = st.pcosts.reshape(n_probs, n_chains).argmin(axis=1) + st.poff
        st.gbest_pcost = st.pcosts[gis].copy()  # per-problem global best
        st.gbest_cost = st.costs[gis].copy()
        st.g_items = st.items[gis].copy()
        st.g_counts = st.counts[gis].copy()
        st.g_live = st.live[gis].copy()
        st.g_kinds = st.bk[gis].copy() if hetero else None
        st.g_UK = st.UK[gis].copy() if hetero else None
        # hetero traces record the penalized cost (monotone); raw otherwise
        now = time.perf_counter() - st.t_start
        st.traces = [
            [(now, float(st.gbest_pcost[j]) if hetero else int(st.gbest_cost[j]))]
            for j in range(n_probs)
        ]
        st.t0s = np.tile(self._chain_t0s(), n_probs)
        st.ri = np.arange(n_rows)
        st.stale = np.zeros(n_rows, dtype=np.int64)
        st.steps = np.zeros(n_rows, dtype=np.int64)
        st.tslots = np.zeros((n_rows, width), dtype=np.int64)
        st.entry_ok = np.zeros((n_rows, width), dtype=bool)
        st.up_prop = np.zeros(n_probs, dtype=np.int64)
        st.up_acc = np.zeros(n_probs, dtype=np.int64)
        st.n_u = 6 if hetero else 4
        st.u_all = np.zeros((st.n_moves, st.n_u, n_rows))
        st.u_metro = np.zeros(n_rows)
        st.it = 0
        st.done = False
        st.frozen = False
        return st

    def _block_eval(self, st: _BlockState, req: tuple) -> np.ndarray:
        """Answer one `_block_gen` step request with a direct kernel call
        (the non-fused dispatch path; ``core.portfolio``'s fused driver
        answers the same requests through ``binpack_portfolio_step``)."""
        from repro.kernels.binpack_sa_step.ops import sa_step_deltas

        old_w, old_h, new_w, new_h, old_k, new_k = req
        if old_k is not None:
            return sa_step_deltas(
                old_w, old_h, new_w, new_h, backend=st.backend,
                interpret=st.interpret, old_k=old_k, new_k=new_k,
                kind_tables=st.kt, mesh=st.mesh,
            )
        return sa_step_deltas(
            old_w, old_h, new_w, new_h, modes=st.modes0,
            backend=st.backend, interpret=st.interpret, mesh=st.mesh,
        )

    def _block_run(self, st: _BlockState, it_limit: int | None = None) -> None:
        """Advance the fleet until ``it_limit`` (a portfolio barrier), the
        iteration budget, the wall cap, or fleet-wide freezing — by driving
        `_block_gen` and answering every step request with the fused
        delta-cost kernel directly.  All state lives in ``st``, so a
        barriered run is bit-identical to an uninterrupted one."""
        from repro.kernels.binpack_sa_step.ops import sa_step_deltas

        hetero = st.hetero
        gen = self._block_gen(st, it_limit)
        req = next(gen, None)
        while req is not None:
            old_w, old_h, new_w, new_h, old_k, new_k = req
            if hetero:
                d_e = sa_step_deltas(
                    old_w, old_h, new_w, new_h, backend=st.backend,
                    interpret=st.interpret, old_k=old_k, new_k=new_k,
                    kind_tables=st.kt, mesh=st.mesh,
                )
            else:
                d_e = sa_step_deltas(
                    old_w, old_h, new_w, new_h, modes=st.modes0,
                    backend=st.backend, interpret=st.interpret, mesh=st.mesh,
                )
            try:
                req = gen.send(d_e)
            except StopIteration:
                break

    def _block_gen(self, st: _BlockState, it_limit: int | None = None):
        """The fleet hot loop as a *step-request generator*.

        Yields one ``(old_w, old_h, new_w, new_h, old_k, new_k)`` touched-
        bin geometry request per annealing step (kind lanes are ``None`` on
        single-kind problems) and expects the ``(R,)`` int64 delta-cost
        vector back via ``send()`` — i.e. exactly the inputs and output of
        ``binpack_sa_step.ops.sa_step_deltas``.  Everything else (proposal,
        Metropolis, rollback/commit, best tracking, exchange) happens
        inside, so every consumer — `_block_run`'s direct kernel driver or
        the portfolio's fused GA+SA dispatcher — advances the *same* loop
        body and produces bit-identical trajectories.  Consumers must drain
        the generator to ``StopIteration`` so the rebound loop state is
        written back to ``st``."""
        from repro.kernels.binpack_sa_step.ops import metropolis_mask

        limit = (
            self.max_iterations if it_limit is None
            else min(self.max_iterations, it_limit)
        )
        n_probs, n_chains, n_rows = st.n_probs, self.n_chains, st.n_rows
        n_moves, width = st.n_moves, 2 * st.n_moves
        batch, probs, rngs = st.batch, st.probs, st.rngs
        hetero = st.hetero
        lam = self.inventory_penalty
        pk = self.p_kind if hetero else 0.0
        n_kinds, any_bounded = st.n_kinds, st.any_bounded
        t_start = st.t_start
        pi, caps_r = st.pi, st.caps_r
        wtab, dtab, ltab, sentinel = st.wtab, st.dtab, st.ltab, st.sentinel
        poff, t0s, ri = st.poff, st.t0s, st.ri
        tslots, entry_ok = st.tslots, st.entry_ok
        up_prop, up_acc = st.up_prop, st.up_acc
        n_u, u_all, u_metro = st.n_u, st.u_all, st.u_metro
        traces = st.traces
        gbest_pcost, gbest_cost = st.gbest_pcost, st.gbest_cost
        g_items, g_counts, g_live = st.g_items, st.g_counts, st.g_live
        g_kinds, g_UK, UK = st.g_kinds, st.g_UK, st.UK
        steps = st.steps
        # rebound across iterations — written back to st on every exit
        items, counts = st.items, st.counts
        bw, bh, live, bk = st.bw, st.bh, st.live, st.bk
        costs, pcosts = st.costs, st.pcosts
        best_pcosts, stale = st.best_pcosts, st.stale
        it = st.it

        def row_lookup(tab, ids):
            """Per-row buffer-table gather (ids row-aligned, any rank)."""
            if tab.ndim == 1:
                return tab[ids]
            rows = pi.reshape((n_rows,) + (1,) * (ids.ndim - 1))
            return tab[rows, ids]

        def ovf_rows(uk):
            return batch.overflow_rows(uk, pi)

        while it < limit:
            if (it & 0xFF) == 0 and time.perf_counter() - t_start > self.max_seconds:
                st.done = True
                break
            active = stale < self.patience
            act_p = active.reshape(n_probs, n_chains).any(axis=1)
            if not act_p.any():
                st.frozen = True
                st.done = True
                break
            # --- propose: each live problem draws one uniform block from its
            # own stream (two extra rows — kind-move gate and kind pick —
            # only on heterogeneous problems, so the single-kind block and
            # its trajectories are untouched); frozen problems draw nothing
            # and their rows stay masked by ``active`` below
            for j in np.flatnonzero(act_p):
                lo = j * n_chains
                u_all[:, :, lo : lo + n_chains] = rngs[j].random(
                    (n_moves, n_u, n_chains)
                )
            if hetero:
                bk_new = bk.copy()  # flips land here; commit is per-chain
            snaps = []
            for m in range(n_moves):
                u = u_all[m]
                src = np.minimum((u[0] * live).astype(np.int64), live - 1)
                dst = np.minimum((u[1] * live).astype(np.int64), live - 1)
                if hetero:
                    # a chain does a RAM-kind flip of bin ``src`` this move
                    # instead of a buffer swap
                    kflip = active & (u[4] < pk)
                    idxf = np.flatnonzero(kflip)
                    if idxf.size:
                        shift = 1 + np.minimum(
                            (u[5, idxf] * (n_kinds - 1)).astype(np.int64),
                            n_kinds - 2,
                        )
                        bk_new[idxf, src[idxf]] = (
                            bk_new[idxf, src[idxf]] + shift
                        ) % n_kinds
                else:
                    kflip = None
                ok = active & (live >= 2) & (src != dst)
                if hetero:
                    ok &= ~kflip
                cnt_s = counts[ri, src]
                ok &= cnt_s > 0
                item_k = np.minimum(
                    (u[2] * cnt_s).astype(np.int64), np.maximum(cnt_s - 1, 0)
                )
                item = items[ri, src, item_k]  # masked below where ~ok
                cnt_d = counts[ri, dst]
                item_safe = np.where(item >= 0, item, sentinel)
                if self.intra_layer:
                    dst_first = items[ri, dst, 0]
                    ok &= (cnt_d == 0) | (
                        row_lookup(
                            ltab, np.where(dst_first >= 0, dst_first, sentinel)
                        )
                        == row_lookup(ltab, item_safe)
                    )
                full = cnt_d >= caps_r
                jd = np.minimum(
                    (u[3] * cnt_d).astype(np.int64), np.maximum(cnt_d - 1, 0)
                )
                other = items[ri, dst, jd]
                swap = ok & full
                if self.intra_layer:
                    src_first = items[ri, src, 0]
                    swap &= (
                        row_lookup(ltab, np.where(other >= 0, other, sentinel))
                        == row_lookup(
                            ltab, np.where(src_first >= 0, src_first, sentinel)
                        )
                    )
                move = ok & ~full
                applied = move | swap
                # full-row snapshots make rollback a pure scatter
                snaps.append(
                    (src, dst, applied,
                     items[ri, src], items[ri, dst], cnt_s, cnt_d)
                )
                idx = np.flatnonzero(swap)
                if idx.size:
                    items[idx, dst[idx], jd[idx]] = item[idx]
                    items[idx, src[idx], item_k[idx]] = other[idx]
                idx = np.flatnonzero(move)
                if idx.size:
                    # remove: swap the picked slot with the last, shrink
                    items[idx, src[idx], item_k[idx]] = items[
                        idx, src[idx], cnt_s[idx] - 1
                    ]
                    items[idx, src[idx], cnt_s[idx] - 1] = -1
                    counts[idx, src[idx]] -= 1
                    # append
                    items[idx, dst[idx], cnt_d[idx]] = item[idx]
                    counts[idx, dst[idx]] += 1
                tslots[:, 2 * m] = src
                tslots[:, 2 * m + 1] = dst
                # a kind flip touches only the src slot (geometry unchanged,
                # kind lane differs); a swap touches both slots
                entry_ok[:, 2 * m] = applied | kflip if hetero else applied
                entry_ok[:, 2 * m + 1] = applied
            # a bin touched twice contributes one delta term (first entry wins)
            for a in range(1, width):
                for b in range(a):
                    entry_ok[:, a] &= ~(
                        entry_ok[:, b] & (tslots[:, a] == tslots[:, b])
                    )
            # --- fused delta-cost step over every chain of every problem
            sel = np.where(entry_ok, tslots, 0)
            rows = ri[:, None]
            old_w = np.where(entry_ok, bw[rows, sel], 0).astype(np.int32)
            old_h = np.where(entry_ok, bh[rows, sel], 0).astype(np.int32)
            slot_items = items[rows, sel, :]  # (R, width, cap_max)
            ids = np.where(slot_items >= 0, slot_items, sentinel)
            new_w = np.where(
                entry_ok, row_lookup(wtab, ids).max(-1), 0
            ).astype(np.int32)
            new_h = np.where(
                entry_ok, row_lookup(dtab, ids).sum(-1), 0
            ).astype(np.int32)
            if hetero:
                old_k = np.where(entry_ok, bk[rows, sel], 0).astype(np.int32)
                new_k = np.where(entry_ok, bk_new[rows, sel], 0).astype(np.int32)
                d_e = yield (old_w, old_h, new_w, new_h, old_k, new_k)
                if any_bounded:
                    # inventory-penalty delta, vectorized over all rows: the
                    # per-kind primitive usage change of the touched slots
                    # (mode tables are fleet-shared; counts are per problem)
                    po = probs[0].bin_primitives_many(old_w, old_h, old_k)
                    pn = probs[0].bin_primitives_many(new_w, new_h, new_k)
                    dUK = np.zeros((n_rows, n_kinds), dtype=np.int64)
                    for kk in range(n_kinds):
                        dUK[:, kk] = ((new_k == kk) * pn).sum(1) - (
                            (old_k == kk) * po
                        ).sum(1)
                    pen = lam * (ovf_rows(UK + dUK) - ovf_rows(UK))
                    d_tot = d_e + pen
                else:
                    dUK = None  # unbounded inventory never overflows
                    d_tot = d_e
            else:
                d_e = yield (old_w, old_h, new_w, new_h, None, None)
                d_tot = d_e
            # --- Metropolis acceptance: per-problem draws, one batched rule
            temps = t0s / (1.0 + self.rc * it)
            for j in np.flatnonzero(act_p):
                lo = j * n_chains
                u_metro[lo : lo + n_chains] = rngs[j].random(n_chains)
            accept = metropolis_mask(d_tot, temps, u_metro) & active
            # --- roll back rejected chains (reverse move order)
            reject = ~accept
            for m in range(n_moves - 1, -1, -1):
                src, dst, applied, s_items, d_items, s_cnt, d_cnt = snaps[m]
                idx = np.flatnonzero(reject & applied)
                if idx.size:
                    items[idx, dst[idx]] = d_items[idx]
                    counts[idx, dst[idx]] = d_cnt[idx]
                    items[idx, src[idx]] = s_items[idx]
                    counts[idx, src[idx]] = s_cnt[idx]
            # --- commit accepted chains
            costs += np.where(accept, d_e, 0)
            com = entry_ok & accept[:, None]
            flat = np.flatnonzero(com.ravel())
            if flat.size:
                rr = flat // width
                cc = tslots.ravel()[flat]
                bw[rr, cc] = new_w.ravel()[flat]
                bh[rr, cc] = new_h.ravel()[flat]
            if hetero:
                np.copyto(bk, bk_new, where=accept[:, None])
                if dUK is not None:
                    UK += dUK * accept[:, None]
                pcosts = costs + lam * ovf_rows(UK)
            else:
                pcosts = costs
            uphill = active & (d_tot > 0)
            up_prop += uphill.reshape(n_probs, n_chains).sum(axis=1)
            up_acc += (uphill & accept).reshape(n_probs, n_chains).sum(axis=1)
            # --- per-chain best / patience bookkeeping
            steps += active
            improved = active & (pcosts < best_pcosts)
            best_pcosts = np.where(improved, pcosts, best_pcosts)
            stale = np.where(improved, 0, np.where(active, stale + 1, stale))
            # --- per-problem global-best tracking
            bi = pcosts.reshape(n_probs, n_chains).argmin(axis=1) + poff
            for j in np.flatnonzero(pcosts[bi] < gbest_pcost):
                r = bi[j]
                gbest_pcost[j] = pcosts[r]
                gbest_cost[j] = costs[r]
                g_items[j] = items[r]
                g_counts[j] = counts[r]
                g_live[j] = live[r]
                if hetero:
                    g_kinds[j] = bk[r]
                    g_UK[j] = UK[r]
                traces[j].append((
                    time.perf_counter() - t_start,
                    float(gbest_pcost[j]) if hetero else int(gbest_cost[j]),
                ))
            # --- periodic per-problem best-chain exchange + compaction
            # (gated on the loop-top activity mask: a frozen problem's
            # standalone run has already exited its loop, so reviving it
            # here — stale[r] = 0 — would draw RNG the standalone run never
            # draws and break the fleet parity contract)
            if self.exchange_every > 0 and (it + 1) % self.exchange_every == 0:
                worst = pcosts.reshape(n_probs, n_chains).argmax(axis=1) + poff
                for j in np.flatnonzero((pcosts[worst] > gbest_pcost) & act_p):
                    r = worst[j]
                    items[r] = g_items[j]
                    counts[r] = g_counts[j]
                    live[r] = g_live[j]
                    ids = np.where(g_items[j] >= 0, g_items[j], sentinel)
                    wt = wtab if wtab.ndim == 1 else wtab[j]
                    dt = dtab if dtab.ndim == 1 else dtab[j]
                    bw[r] = wt[ids].max(-1)
                    bh[r] = dt[ids].sum(-1)
                    costs[r] = gbest_cost[j]
                    if hetero:
                        bk[r] = g_kinds[j]
                        UK[r] = g_UK[j]
                    best_pcosts[r] = min(best_pcosts[r], gbest_pcost[j])
                    stale[r] = 0
                if hetero:
                    pcosts = costs + lam * ovf_rows(UK)
                order = np.argsort(counts == 0, axis=1, kind="stable")
                items = np.take_along_axis(items, order[:, :, None], 1)
                counts = np.take_along_axis(counts, order, 1)
                bw = np.take_along_axis(bw, order, 1)
                bh = np.take_along_axis(bh, order, 1)
                if hetero:
                    bk = np.take_along_axis(bk, order, 1)
                live = (counts > 0).sum(1)
            it += 1
        # --- write the rebound loop state back (in-place arrays already land
        # in st; these are the names the loop rebinds)
        st.items, st.counts = items, counts
        st.bw, st.bh, st.live, st.bk = bw, bh, live, bk
        st.costs, st.pcosts = costs, pcosts
        st.best_pcosts, st.stale = best_pcosts, stale
        st.it = it
        if it >= self.max_iterations:
            st.done = True

    def _block_finish(self, st: _BlockState) -> list[_BlockOut]:
        wall = time.perf_counter() - st.t_start
        hetero, n_chains = st.hetero, self.n_chains
        outs: list[_BlockOut] = []
        for j in range(st.n_probs):
            lo = j * n_chains
            chains = [
                decode_chain_items(
                    st.probs[j], st.items[r], st.counts[r],
                    st.bk[r] if hetero else None,
                )
                for r in range(lo, lo + n_chains)
            ]
            gbest = decode_chain_items(
                st.probs[j], st.g_items[j], st.g_counts[j],
                st.g_kinds[j] if hetero else None,
            )
            outs.append(_BlockOut(
                best=gbest,
                best_cost=int(st.gbest_cost[j]),
                trace=st.traces[j],
                iterations=int(st.steps[lo : lo + n_chains].sum()),
                chains=chains,
                incumbent=int(st.pcosts[lo : lo + n_chains].argmin()),
                uphill=(int(st.up_prop[j]), int(st.up_acc[j])),
                wall=wall,
            ))
        return outs

    def _block_frozen(self, st: _BlockState, j: int) -> bool:
        """True when fleet problem ``j`` has every chain past patience."""
        lo = j * self.n_chains
        return not (st.stale[lo : lo + self.n_chains] < self.patience).any()

    def _block_migrate(self, st: _BlockState, j: int, sol: Solution) -> bool:
        """Portfolio barrier hook: land a migrant into fleet problem ``j``'s
        worst chain slot iff it strictly beats that slot's penalized cost.
        A frozen problem is never touched — and patience counters are never
        reset — so migration cannot revive a problem that already stopped
        drawing RNG (its trajectory stays exactly its standalone one)."""
        if st.done or self._block_frozen(st, j):
            return False
        lam = self.inventory_penalty
        n_chains = self.n_chains
        lo = j * n_chains
        r = lo + int(st.pcosts[lo : lo + n_chains].argmax())
        cost = int(sol.cost())
        ovf = int(sol.inventory_overflow()) if st.hetero else 0
        if cost + lam * ovf >= st.pcosts[r]:
            return False
        nb = st.items.shape[1]
        if len(sol.bins) > nb:  # cannot encode into this fleet's envelope
            return False
        items_row, counts_row = encode_chain_items([sol], st.cap_max, n_slots=nb)
        st.items[r] = items_row[0]
        st.counts[r] = counts_row[0]
        st.live[r] = int((counts_row[0] > 0).sum())
        sol.fill_geometry(st.bw[r], st.bh[r])
        st.costs[r] = cost
        if st.hetero:
            sol.fill_kinds(st.bk[r])
            st.UK[r] = sol.used_primitives()
            st.pcosts[r] = cost + lam * st.batch.overflow_rows(
                st.UK[r : r + 1], st.pi[r : r + 1]
            )[0]
        else:
            st.pcosts[r] = cost  # pcosts aliases costs on single-kind fleets
        st.best_pcosts[r] = min(st.best_pcosts[r], st.pcosts[r])
        return True

    # ------------------------------------------------- portfolio racing hooks
    # Successive-halving racing (portfolio.pack_portfolio(auto=True)) treats
    # the iteration budget as a portfolio-level ledger: a surviving island's
    # budget is *extended* barrier by barrier (reallocation is just a larger
    # ``it_limit``), and an eliminated island simply stops advancing.  Both
    # hooks preserve the trajectory contract: extension only lifts the budget
    # ceiling (never touches patience, RNG, or the wall cap), and elimination
    # reuses the freeze mechanism — a frozen problem draws no RNG, so fleet
    # siblings' streams are untouched.

    def _block_extend(self, st: _BlockState, it_limit: int) -> None:
        """Raise the fleet's iteration budget to at least ``it_limit``,
        reviving a state that stopped *on budget* (never one frozen on
        patience or cut by the wall cap)."""
        if st.done and not st.frozen and st.it >= self.max_iterations:
            st.done = False
        self.max_iterations = max(self.max_iterations, int(it_limit))

    def _block_eliminate(self, st: _BlockState, j: int) -> None:
        """Stop fleet problem ``j`` forever by pushing every chain past
        patience: the loop-top activity mask skips frozen problems before
        any RNG draw, so siblings' streams are bit-identical to a run where
        ``j`` never existed past this point."""
        lo = j * self.n_chains
        st.stale[lo : lo + self.n_chains] = self.patience

    def _scalar_extend(self, st: _ScalarRun, it_limit: int) -> None:
        if st.done and st.stale < self.patience and st.it >= self.max_iterations:
            st.done = False
        self.max_iterations = max(self.max_iterations, int(it_limit))

    def _single_extend(self, st: _SingleChainRun, it_limit: int) -> None:
        if st.done and st.stale < self.patience and st.it >= self.max_iterations:
            st.done = False
        self.max_iterations = max(self.max_iterations, int(it_limit))

    def _loop_eliminate(self, st) -> None:
        """Stop a scalar/single-chain state forever (`_ScalarRun` and
        `_SingleChainRun` both gate their loops on ``st.done``)."""
        st.done = True

    # ------------------------------------------------------------------ result
    def _result(self, best, best_cost, wall, trace, iterations, backend, uphill):
        params = dict(
            t0=self.t0,
            rc=self.rc,
            p_adm_w=self.p_adm_w,
            p_adm_h=self.p_adm_h,
            seed=self.seed,
            backend=backend,
            n_chains=self.n_chains if backend != "legacy" else 1,
        )
        if uphill is not None:
            params["exchange_every"] = self.exchange_every
            params["uphill_proposed"], params["uphill_accepted"] = uphill
        if self._hetero:
            params["p_kind"] = self.p_kind
            params["inventory_penalty"] = self.inventory_penalty
            params["overflow"] = best.inventory_overflow()
        algorithm = "SA-NFD" if self.perturbation == "nfd" else "SA-S"
        if params["n_chains"] > 1:
            algorithm += f"x{params['n_chains']}"
        return PackingResult(
            solution=best,
            cost=best_cost,
            efficiency=best.efficiency(),
            wall_time_s=wall,
            algorithm=algorithm + ("-intra" if self.intra_layer else ""),
            trace=trace,
            iterations=iterations,
            params=params,
        )
