from .packing import pack_documents  # noqa: F401
from .pipeline import DataConfig, SyntheticTokenPipeline  # noqa: F401
