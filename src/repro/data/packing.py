"""Sequence packing via the paper's bin packer (second first-class use).

Packing variable-length documents into fixed-length training sequences IS
cardinality-constrained bin packing: bins = training sequences of capacity
``seq_len`` tokens, items = documents, cardinality = max documents per
sequence (bounds the block-diagonal attention-mask bookkeeping).  We reuse
the core machinery verbatim with a single-mode "BRAM" of one
``seq_len``-token row: minimizing BRAM count minimizes the number of padded
sequences, and NFD's grid-gap admission rule naturally fills sequences
toward the token boundary.
"""
from __future__ import annotations

import numpy as np

from repro.core import BRAMSpec, Buffer, PackingProblem, pack


def pack_documents(
    doc_lengths: list[int],
    seq_len: int,
    max_docs_per_seq: int = 8,
    algorithm: str = "ffd",
    seed: int = 0,
) -> list[list[int]]:
    """Group document indices into sequences of capacity seq_len.

    Documents longer than seq_len must be pre-split by the caller.
    Returns a list of sequences, each a list of document indices.
    """
    if any(d > seq_len for d in doc_lengths):
        raise ValueError("split documents longer than seq_len first")
    buffers = [
        Buffer(width=1, depth=int(d), layer=0, name=f"doc{i}")
        for i, d in enumerate(doc_lengths)
    ]
    prob = PackingProblem(
        buffers,
        bram=BRAMSpec(modes=((1, seq_len),), capacity_bits=seq_len),
        max_items=max_docs_per_seq,
        name="seqpack",
    )
    result = pack(prob, algorithm, seed=seed, max_seconds=2.0, p_adm_w=1.0)
    result.solution.validate()
    # split any bin that exceeds capacity (NFD admission may cross the token
    # boundary when it reduces grid waste; sequences cannot)
    sequences: list[list[int]] = []
    for b in result.solution.bins:
        cur: list[int] = []
        used = 0
        for i in b:
            d = int(doc_lengths[i])
            if used + d > seq_len and cur:
                sequences.append(cur)
                cur, used = [], 0
            cur.append(i)
            used += d
        if cur:
            sequences.append(cur)
    return sequences


def packing_efficiency(
    sequences: list[list[int]], doc_lengths: list[int], seq_len: int
) -> float:
    tokens = sum(doc_lengths)
    return tokens / max(1, len(sequences) * seq_len)
