"""Deterministic, checkpointable synthetic token pipeline.

Generates documents with a reproducible counter-based PRNG (stateless in
(seed, index), so any batch can be regenerated from the iterator state),
packs them into fixed-length sequences with the paper's bin packer, and
yields sharded-ready numpy batches.  The iterator state is two integers —
it snapshots into every checkpoint and restores exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 32_000
    mean_doc_len: int = 384
    max_docs_per_seq: int = 8
    seed: int = 0
    pack: bool = True  # NFD sequence packing vs one doc per row


class SyntheticTokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.doc_index = 0  # persistent iterator state
        self.step = 0

    # ------------------------------------------------------------- state
    def state(self) -> dict:
        return {"doc_index": self.doc_index, "step": self.step}

    def restore(self, state: dict) -> None:
        self.doc_index = int(state["doc_index"])
        self.step = int(state["step"])

    # ----------------------------------------------------------- internals
    def _doc(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed << 32) ^ idx)
        length = int(
            np.clip(rng.lognormal(np.log(self.cfg.mean_doc_len), 0.6), 8,
                    self.cfg.seq_len)
        )
        return rng.integers(2, self.cfg.vocab_size, size=length, dtype=np.int32)

    # ------------------------------------------------------------- batches
    def next_batch(self) -> dict:
        cfg = self.cfg
        rows_needed = cfg.global_batch
        tokens = np.zeros((rows_needed, cfg.seq_len), np.int32)
        targets = np.full((rows_needed, cfg.seq_len), -1, np.int32)
        segments = np.zeros((rows_needed, cfg.seq_len), np.int32)

        if cfg.pack:
            # draw a pool of docs ~1.2x the token budget, pack, take rows
            docs: list[np.ndarray] = []
            budget = int(rows_needed * cfg.seq_len * 1.2)
            got = 0
            while got < budget:
                d = self._doc(self.doc_index)
                self.doc_index += 1
                docs.append(d)
                got += len(d)
            from .packing import pack_documents

            seqs = pack_documents(
                [len(d) for d in docs], cfg.seq_len, cfg.max_docs_per_seq,
                seed=cfg.seed + self.step,
            )
            for row in range(rows_needed):
                seq = seqs[row % len(seqs)]
                off = 0
                for si, di in enumerate(seq):
                    d = docs[di]
                    n = min(len(d), cfg.seq_len - off)
                    if n <= 1:
                        break
                    tokens[row, off : off + n] = d[:n]
                    targets[row, off : off + n - 1] = d[1:n]
                    segments[row, off : off + n] = si + 1
                    off += n
        else:
            for row in range(rows_needed):
                d = self._doc(self.doc_index)
                self.doc_index += 1
                n = min(len(d), cfg.seq_len)
                tokens[row, :n] = d[:n]
                targets[row, : n - 1] = d[1:n]
                segments[row, :n] = 1
        self.step += 1
        return {"tokens": tokens, "targets": targets, "segments": segments}
