from .kernel import (  # noqa: F401
    binpack_fitness_kinds_pallas,
    binpack_fitness_pallas,
)
from .ops import population_costs  # noqa: F401
from .ref import binpack_fitness_kinds_ref, binpack_fitness_ref  # noqa: F401
