"""Batched bin-cost fitness kernel (GA generations, DSE fleets).

`ops.population_costs` reduces padded (P, NB) — or, with a leading problem
axis, (NP, P, NB) — bin-geometry matrices to per-individual totals in one
call; see docs/DESIGN.md section 10 for the batching axes and the
padding/masking contract.
"""
from .kernel import (  # noqa: F401
    binpack_fitness_kinds_pallas,
    binpack_fitness_pallas,
)
from .ops import population_costs  # noqa: F401
from .ref import binpack_fitness_kinds_ref, binpack_fitness_ref  # noqa: F401
