"""Pallas TPU kernel: population-parallel bin-packing fitness evaluation.

The GA's compute hot-spot is evaluating the BRAM cost of every individual
every generation:  cost(bin) = min_m ceil(w / w_m) * ceil(h / d_m)  over the
BRAM aspect modes.  Pure integer VPU work, embarrassingly parallel over
(population x bins) — ideal for lane-parallel evaluation.

Layout: widths/heights are (P, NB) int32, NB padded to a lane multiple;
empty bins carry w = h = 0 and cost 0.  The grid tiles the population; each
program evaluates a (POP_TILE, NB) block entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

POP_TILE = 8  # population rows per program (sublane tile for int32)


def _fitness_kernel(w_ref, h_ref, cost_ref, *, modes):
    w = w_ref[...]
    h = h_ref[...]
    best = jnp.full(w.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
    for mw, md in modes:
        c = ((w + (mw - 1)) // mw) * ((h + (md - 1)) // md)
        best = jnp.minimum(best, c)
    # empty slots (w == 0) cost nothing
    cost_ref[...] = jnp.where(w > 0, best, 0)


def kind_cost_block(w, h, k, kind_tables):
    """Per-kind lane-selected bin cost, shared by every heterogeneous kernel
    body (fitness and SA delta): the kind count is tiny (2-4), so the
    per-kind cost planes are computed unconditionally and lane-selected —
    pure VPU work, no gather.  Empty slots (w == 0) cost nothing."""
    out = jnp.zeros(w.shape, jnp.int32)
    for ki, (weight, modes) in enumerate(kind_tables):
        best = jnp.full(w.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
        for mw, md in modes:
            c = ((w + (mw - 1)) // mw) * ((h + (md - 1)) // md)
            best = jnp.minimum(best, c)
        out = jnp.where(k == ki, best * jnp.int32(weight), out)
    return jnp.where(w > 0, out, 0)


def _fitness_kinds_kernel(w_ref, h_ref, k_ref, cost_ref, *, kind_tables):
    """Heterogeneous variant: a kind-index plane selects, per bin, which
    static mode table and unit weight apply."""
    cost_ref[...] = kind_cost_block(
        w_ref[...], h_ref[...], k_ref[...], kind_tables
    )


@functools.partial(jax.jit, static_argnames=("modes", "interpret"))
def binpack_fitness_pallas(
    widths: jax.Array,  # (P, NB) int32
    heights: jax.Array,  # (P, NB) int32
    modes: tuple[tuple[int, int], ...],
    interpret: bool = True,  # CPU host: validate via interpreter
) -> jax.Array:
    p, nb = widths.shape
    pad_p = (-p) % POP_TILE
    pad_b = (-nb) % 128
    if pad_p or pad_b:
        widths = jnp.pad(widths, ((0, pad_p), (0, pad_b)))
        heights = jnp.pad(heights, ((0, pad_p), (0, pad_b)))
    pp, nbp = widths.shape
    out = pl.pallas_call(
        functools.partial(_fitness_kernel, modes=modes),
        grid=(pp // POP_TILE,),
        in_specs=[
            pl.BlockSpec((POP_TILE, nbp), lambda i: (i, 0)),
            pl.BlockSpec((POP_TILE, nbp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((POP_TILE, nbp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pp, nbp), jnp.int32),
        interpret=interpret,
    )(widths, heights)
    return out[:p, :nb]


@functools.partial(jax.jit, static_argnames=("kind_tables", "interpret"))
def binpack_fitness_kinds_pallas(
    widths: jax.Array,  # (P, NB) int32
    heights: jax.Array,  # (P, NB) int32
    kinds: jax.Array,  # (P, NB) int32 RAM-kind indices
    kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...],
    interpret: bool = True,  # CPU host: validate via interpreter
) -> jax.Array:
    p, nb = widths.shape
    pad_p = (-p) % POP_TILE
    pad_b = (-nb) % 128
    if pad_p or pad_b:
        pad = ((0, pad_p), (0, pad_b))
        widths = jnp.pad(widths, pad)
        heights = jnp.pad(heights, pad)
        kinds = jnp.pad(kinds, pad)  # kind 0 on w == 0 slots costs nothing
    pp, nbp = widths.shape
    out = pl.pallas_call(
        functools.partial(_fitness_kinds_kernel, kind_tables=kind_tables),
        grid=(pp // POP_TILE,),
        in_specs=[pl.BlockSpec((POP_TILE, nbp), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((POP_TILE, nbp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pp, nbp), jnp.int32),
        interpret=interpret,
    )(widths, heights, kinds)
    return out[:p, :nb]
