"""Jit'd wrapper: per-individual total BRAM cost for a padded population.

This is the GA's generation-evaluation primitive: rows are individuals,
columns are bins, entries are the bin geometry; empty (padded) slots carry
``width == 0`` and cost nothing.  ``backend="auto"`` picks the Pallas kernel
when a TPU is attached and the pure-jnp reference otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import BRAM18_MODES

from .kernel import binpack_fitness_pallas
from .ref import binpack_fitness_ref


@functools.partial(jax.jit, static_argnames=("modes",))
def _ref_totals(widths, heights, modes):
    return jnp.sum(binpack_fitness_ref(widths, heights, modes), axis=1)


def population_costs(
    widths, heights, modes=BRAM18_MODES, backend: str = "pallas", interpret=True
):
    """(P, NB) geometry -> (P,) total cost per individual."""
    if backend == "auto":
        if jax.default_backend() == "tpu":
            backend, interpret = "pallas", False
        else:
            backend = "ref"
    if backend == "pallas":
        per_bin = binpack_fitness_pallas(widths, heights, tuple(modes), interpret)
        return jnp.sum(per_bin, axis=1)
    if backend != "ref":
        raise ValueError(f"unknown backend {backend!r}; options: auto, pallas, ref")
    return _ref_totals(widths, heights, tuple(modes))
