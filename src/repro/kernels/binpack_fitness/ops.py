"""Jit'd wrapper: per-individual total RAM cost for a padded population.

This is the GA's generation-evaluation primitive: rows are individuals,
columns are bins, entries are the bin geometry; empty (padded) slots carry
``width == 0`` and cost nothing.  ``backend="auto"`` picks the Pallas kernel
when a TPU is attached and the pure-jnp reference otherwise.

Heterogeneous OCM problems pass a parallel ``kinds`` matrix plus the
problem's precomputed ``kind_tables`` (``((weight, modes), ...)`` per RAM
kind); the homogeneous call signature and its jit cache are untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import BRAM18_MODES

from .kernel import binpack_fitness_kinds_pallas, binpack_fitness_pallas
from .ref import binpack_fitness_kinds_ref, binpack_fitness_ref


@functools.partial(jax.jit, static_argnames=("modes",))
def _ref_totals(widths, heights, modes):
    return jnp.sum(binpack_fitness_ref(widths, heights, modes), axis=1)


@functools.partial(jax.jit, static_argnames=("kind_tables",))
def _ref_totals_kinds(widths, heights, kinds, kind_tables):
    return jnp.sum(
        binpack_fitness_kinds_ref(widths, heights, kinds, kind_tables), axis=1
    )


def population_costs(
    widths,
    heights,
    modes=BRAM18_MODES,
    backend: str = "pallas",
    interpret=True,
    kinds=None,
    kind_tables=None,
):
    """(P, NB) geometry -> (P,) total cost per individual.

    ``kinds`` (a (P, NB) int matrix of RAM-kind indices) together with
    ``kind_tables`` routes evaluation through per-kind mode tables; without
    them the single mode set ``modes`` applies to every bin.

    A leading *problem axis* is also accepted on every backend:
    ``(NP, P, NB)`` inputs return ``(NP, P)`` totals, evaluating a whole
    fleet of padded problems in one call (the DSE sweep path —
    docs/DESIGN.md section 10).  Padded lanes are masked by the zero-width
    convention: a padded bin slot (or an entirely padded problem row) has
    width 0 and costs nothing.
    """
    widths = jnp.asarray(widths)
    heights = jnp.asarray(heights)
    if widths.ndim == 3:
        np_, p_, nb_ = widths.shape
        totals = population_costs(
            widths.reshape(np_ * p_, nb_),
            heights.reshape(np_ * p_, nb_),
            modes=modes,
            backend=backend,
            interpret=interpret,
            kinds=None if kinds is None else jnp.asarray(kinds).reshape(np_ * p_, nb_),
            kind_tables=kind_tables,
        )
        return totals.reshape(np_, p_)
    if backend == "auto":
        if jax.default_backend() == "tpu":
            backend, interpret = "pallas", False
        else:
            backend = "ref"
    if kinds is not None:
        if kind_tables is None:
            raise ValueError("kinds requires kind_tables")
        kind_tables = tuple((int(w), tuple(m)) for w, m in kind_tables)
        if backend == "pallas":
            per_bin = binpack_fitness_kinds_pallas(
                widths, heights, kinds, kind_tables, interpret
            )
            return jnp.sum(per_bin, axis=1)
        if backend != "ref":
            raise ValueError(
                f"unknown backend {backend!r}; options: auto, pallas, ref"
            )
        return _ref_totals_kinds(widths, heights, kinds, kind_tables)
    if backend == "pallas":
        per_bin = binpack_fitness_pallas(widths, heights, tuple(modes), interpret)
        return jnp.sum(per_bin, axis=1)
    if backend != "ref":
        raise ValueError(f"unknown backend {backend!r}; options: auto, pallas, ref")
    return _ref_totals(widths, heights, tuple(modes))
