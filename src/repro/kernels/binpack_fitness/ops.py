"""Jit'd wrapper: per-individual total BRAM cost for a padded population."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.problem import BRAM18_MODES

from .kernel import binpack_fitness_pallas
from .ref import binpack_fitness_ref


def population_costs(
    widths, heights, modes=BRAM18_MODES, backend: str = "pallas", interpret=True
):
    """(P, NB) geometry -> (P,) total cost per individual."""
    if backend == "pallas":
        per_bin = binpack_fitness_pallas(widths, heights, tuple(modes), interpret)
    else:
        per_bin = binpack_fitness_ref(widths, heights, tuple(modes))
    return jnp.sum(per_bin, axis=1, dtype=jnp.int64)
