"""Jit'd wrapper: per-individual total RAM cost for a padded population.

This is the GA's generation-evaluation primitive: rows are individuals,
columns are bins, entries are the bin geometry; empty (padded) slots carry
``width == 0`` and cost nothing.  ``backend="auto"`` picks the Pallas kernel
when a TPU is attached and the pure-jnp reference otherwise.

Heterogeneous OCM problems pass a parallel ``kinds`` matrix plus the
problem's precomputed ``kind_tables`` (``((weight, modes), ...)`` per RAM
kind); the homogeneous call signature and its jit cache are untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import BRAM18_MODES

from .kernel import binpack_fitness_kinds_pallas, binpack_fitness_pallas
from .ref import binpack_fitness_kinds_ref, binpack_fitness_ref


@functools.partial(jax.jit, static_argnames=("modes",))
def _ref_totals(widths, heights, modes):
    return jnp.sum(binpack_fitness_ref(widths, heights, modes), axis=1)


@functools.partial(jax.jit, static_argnames=("kind_tables",))
def _ref_totals_kinds(widths, heights, kinds, kind_tables):
    return jnp.sum(
        binpack_fitness_kinds_ref(widths, heights, kinds, kind_tables), axis=1
    )


def population_costs(
    widths,
    heights,
    modes=BRAM18_MODES,
    backend: str = "pallas",
    interpret=True,
    kinds=None,
    kind_tables=None,
    mesh=None,
):
    """(P, NB) geometry -> (P,) total cost per individual.

    ``kinds`` (a (P, NB) int matrix of RAM-kind indices) together with
    ``kind_tables`` routes evaluation through per-kind mode tables; without
    them the single mode set ``modes`` applies to every bin.

    A leading *problem axis* is also accepted on every backend:
    ``(NP, P, NB)`` inputs return ``(NP, P)`` totals, evaluating a whole
    fleet of padded problems in one call (the DSE sweep path —
    docs/DESIGN.md section 10).  Padded lanes are masked by the zero-width
    convention: a padded bin slot (or an entirely padded problem row) has
    width 0 and costs nothing.

    ``mesh`` (a 1-D ``("prob",)`` mesh from ``launch.mesh.make_sweep_mesh``)
    row-shards the evaluation across devices via ``shard_map``: the leading
    axis is zero-padded to a multiple of the mesh size, each device costs
    its contiguous row block, and results are bit-identical to the
    unsharded call (exact integer arithmetic — docs/DESIGN.md section 14).
    """
    widths = jnp.asarray(widths)
    heights = jnp.asarray(heights)
    if widths.ndim == 3:
        np_, p_, nb_ = widths.shape
        totals = population_costs(
            widths.reshape(np_ * p_, nb_),
            heights.reshape(np_ * p_, nb_),
            modes=modes,
            backend=backend,
            interpret=interpret,
            kinds=None if kinds is None else jnp.asarray(kinds).reshape(np_ * p_, nb_),
            kind_tables=kind_tables,
            mesh=mesh,
        )
        return totals.reshape(np_, p_)
    if backend == "auto":
        if jax.default_backend() == "tpu":
            backend, interpret = "pallas", False
        else:
            backend = "ref"
    if mesh is not None:
        return _population_costs_sharded(
            widths, heights, modes, backend, interpret, kinds, kind_tables,
            mesh,
        )
    if kinds is not None:
        if kind_tables is None:
            raise ValueError("kinds requires kind_tables")
        kind_tables = tuple((int(w), tuple(m)) for w, m in kind_tables)
        if backend == "pallas":
            per_bin = binpack_fitness_kinds_pallas(
                widths, heights, kinds, kind_tables, interpret
            )
            return jnp.sum(per_bin, axis=1)
        if backend != "ref":
            raise ValueError(
                f"unknown backend {backend!r}; options: auto, pallas, ref"
            )
        return _ref_totals_kinds(widths, heights, kinds, kind_tables)
    if backend == "pallas":
        per_bin = binpack_fitness_pallas(widths, heights, tuple(modes), interpret)
        return jnp.sum(per_bin, axis=1)
    if backend != "ref":
        raise ValueError(f"unknown backend {backend!r}; options: auto, pallas, ref")
    return _ref_totals(widths, heights, tuple(modes))


_SHARD_CACHE: dict = {}


def _population_costs_sharded(
    widths, heights, modes, backend, interpret, kinds, kind_tables, mesh
):
    """Row-sharded evaluation over the ``("prob",)`` mesh (PR 8)."""
    from repro.kernels.probshard import mesh_size, pad_rows, row_shard

    k = mesh_size(mesh)
    hetero = kinds is not None
    if hetero:
        if kind_tables is None:
            raise ValueError("kinds requires kind_tables")
        kind_tables = tuple((int(w), tuple(m)) for w, m in kind_tables)
        key = (mesh, backend, interpret, kind_tables)
    else:
        modes = tuple(modes)
        key = (mesh, backend, interpret, modes)
    fn = _SHARD_CACHE.get(key)
    if fn is None:
        if backend == "pallas":
            if hetero:
                def body(w, h, kk):
                    return jnp.sum(
                        binpack_fitness_kinds_pallas(
                            w, h, kk, kind_tables, interpret
                        ),
                        axis=1,
                    )
            else:
                def body(w, h):
                    return jnp.sum(
                        binpack_fitness_pallas(w, h, modes, interpret), axis=1
                    )
        elif backend == "ref":
            if hetero:
                def body(w, h, kk):
                    return jnp.sum(
                        binpack_fitness_kinds_ref(w, h, kk, kind_tables),
                        axis=1,
                    )
            else:
                def body(w, h):
                    return jnp.sum(binpack_fitness_ref(w, h, modes), axis=1)
        else:
            raise ValueError(
                f"unknown backend {backend!r}; options: auto, pallas, ref"
            )
        fn = _SHARD_CACHE[key] = row_shard(mesh, body)
    args = (widths, heights) + ((kinds,) if hetero else ())
    args, n = pad_rows(args, k)
    return fn(*(jnp.asarray(a) for a in args))[:n]
