"""Pure-jnp oracle for the binpack fitness kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binpack_fitness_ref(
    widths: jax.Array, heights: jax.Array, modes: tuple[tuple[int, int], ...]
) -> jax.Array:
    w = widths.astype(jnp.int32)
    h = heights.astype(jnp.int32)
    costs = [
        -(-w // mw) * -(-h // md) for mw, md in modes
    ]
    best = jnp.min(jnp.stack(costs), axis=0).astype(jnp.int32)
    return jnp.where(widths > 0, best, 0)
