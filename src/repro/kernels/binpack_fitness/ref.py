"""Pure-jnp oracle for the binpack fitness kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binpack_fitness_ref(
    widths: jax.Array, heights: jax.Array, modes: tuple[tuple[int, int], ...]
) -> jax.Array:
    w = widths.astype(jnp.int32)
    h = heights.astype(jnp.int32)
    costs = [
        -(-w // mw) * -(-h // md) for mw, md in modes
    ]
    best = jnp.min(jnp.stack(costs), axis=0).astype(jnp.int32)
    return jnp.where(widths > 0, best, 0)


def binpack_fitness_kinds_ref(
    widths: jax.Array,
    heights: jax.Array,
    kinds: jax.Array,
    kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...],
) -> jax.Array:
    """Heterogeneous variant: per-bin RAM-kind indices select the mode table
    and the unit weight (``kind_tables[k] = (weight, modes)``)."""
    out = jnp.zeros(widths.shape, dtype=jnp.int32)
    for k, (weight, modes) in enumerate(kind_tables):
        ck = binpack_fitness_ref(widths, heights, modes) * jnp.int32(weight)
        out = jnp.where(kinds == k, ck, out)
    return out
