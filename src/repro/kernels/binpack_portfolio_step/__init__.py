"""Fused portfolio step kernel: GA generation fitness + SA fleet deltas.

`ops.portfolio_step` evaluates one stacked GA population-fitness block
(``binpack_fitness``) and one SA fleet delta-cost step
(``binpack_sa_step``) in a single combined call — the device program behind
``core.portfolio``'s fused barrier dispatch (docs/DESIGN.md section 13).
"""
from .ops import portfolio_step  # noqa: F401
