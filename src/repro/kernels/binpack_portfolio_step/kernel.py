"""Pallas variant of the fused portfolio step.

Composes the two existing Pallas kernels (``binpack_fitness``'s population
evaluator and ``binpack_sa_step``'s delta-cost step) under one jit, so a TPU
run launches ONE compiled program per fused barrier segment; off-TPU the
interpreter path validates the exact same composition.  Both kernels are
exact-integer, so the fused results stay bit-identical to the separate
dispatches (pinned in ``tests/test_kernels.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.binpack_fitness.kernel import (
    binpack_fitness_kinds_pallas,
    binpack_fitness_pallas,
)
from repro.kernels.binpack_sa_step.kernel import (
    sa_step_deltas_kinds_pallas,
    sa_step_deltas_pallas,
)


@functools.partial(jax.jit, static_argnames=("modes", "interpret"))
def portfolio_step_pallas(
    W, H, old_w, old_h, new_w, new_h, modes, interpret
):
    nb = W.shape[-1]
    per_bin = binpack_fitness_pallas(
        W.reshape(-1, nb), H.reshape(-1, nb), modes, interpret
    )
    totals = jnp.sum(per_bin, axis=1).reshape(W.shape[:-1])
    deltas = sa_step_deltas_pallas(old_w, old_h, new_w, new_h, modes, interpret)
    return totals, deltas


@functools.partial(jax.jit, static_argnames=("kind_tables", "interpret"))
def portfolio_step_kinds_pallas(
    W, H, Km, old_w, old_h, old_k, new_w, new_h, new_k, kind_tables, interpret
):
    nb = W.shape[-1]
    per_bin = binpack_fitness_kinds_pallas(
        W.reshape(-1, nb), H.reshape(-1, nb), Km.reshape(-1, nb),
        kind_tables, interpret,
    )
    totals = jnp.sum(per_bin, axis=1).reshape(W.shape[:-1])
    deltas = sa_step_deltas_kinds_pallas(
        old_w, old_h, old_k, new_w, new_h, new_k, kind_tables, interpret
    )
    return totals, deltas
