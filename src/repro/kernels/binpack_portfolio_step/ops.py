"""Dispatcher for the fused portfolio step: GA fitness + SA deltas at once.

``portfolio_step`` is the device program behind ``core.portfolio``'s fused
barrier dispatch: one call evaluates a stacked GA generation's population
fitness (the ``binpack_fitness`` contract) AND one SA fleet annealing step's
touched-bin delta costs (the ``binpack_sa_step`` contract).  Backends:

* ``"python"`` — vectorized numpy for both halves; no JAX on the hot path.
* ``"ref"`` — ONE jit'd pure-jnp program computing both halves.
* ``"pallas"`` — the two Pallas kernels composed under one jit (a single
  compiled program per fused segment on TPU; interpreter-validated off-TPU).
* ``"auto"`` — ``pallas`` when a TPU is attached, else ``ref``.

All backends use exact integer arithmetic: the returned totals are
bit-identical to ``binpack_fitness.ops.population_costs`` and the deltas to
``binpack_sa_step.ops.sa_step_deltas`` for the same inputs, so a fused
portfolio barrier cannot change any engine trajectory (pinned in
``tests/test_kernels.py`` and ``tests/test_portfolio_concurrent.py``).
"""
from __future__ import annotations

import numpy as np

from repro.core.problem import BRAM18_MODES
from repro.kernels.binpack_sa_step.ops import (
    _bin_costs_kinds_numpy,
    _bin_costs_numpy,
)

BACKENDS = ("auto", "python", "ref", "pallas")


def portfolio_step(
    W,
    H,
    old_w,
    old_h,
    new_w,
    new_h,
    modes=BRAM18_MODES,
    backend: str = "auto",
    interpret: bool = True,
    kinds=None,
    old_k=None,
    new_k=None,
    kind_tables=None,
    mesh=None,
) -> tuple[np.ndarray, np.ndarray]:
    """One fused call: ``(W, H)`` population geometry (any leading shape,
    bins on the last axis) plus ``(R, T)`` touched-bin SA step geometry ->
    ``(totals, deltas)``.

    ``totals`` is float64 with ``W``'s leading shape (exact integer values,
    matching ``GeneticPacker._batched_costs``); ``deltas`` is ``(R,)``
    int64 (matching ``sa_step_deltas``).  Heterogeneous problems pass the
    kind lanes of BOTH halves (``kinds`` for the populations, ``old_k`` /
    ``new_k`` for the touched slots) plus the shared ``kind_tables`` —
    all-or-none, since a portfolio's islands share one problem.

    ``mesh`` (a 1-D ``("prob",)`` sweep mesh) row-shards BOTH halves over
    their leading axes via ``shard_map`` on the jax backends, bit-identically
    (docs/DESIGN.md section 14); the ``"python"`` backend ignores it.
    """
    hetero = kind_tables is not None
    sides = (kinds is not None, old_k is not None, new_k is not None)
    if hetero != all(sides) or (not hetero and any(sides)):
        raise ValueError(
            "kinds/old_k/new_k/kind_tables must be passed together (the "
            "portfolio's islands share one problem) or not at all"
        )
    if backend == "auto":
        backend, interpret = resolve_auto()
    if hetero:
        kind_tables = tuple((int(w), tuple(m)) for w, m in kind_tables)
    else:
        modes = tuple(modes)
    if mesh is not None and backend in ("ref", "pallas"):
        return _portfolio_step_sharded(
            W, H, old_w, old_h, new_w, new_h, modes, backend, interpret,
            kinds, old_k, new_k, kind_tables, mesh,
        )
    if backend == "python":
        if hetero:
            per_bin = _bin_costs_kinds_numpy(W, H, kinds, kind_tables)
            new_c = _bin_costs_kinds_numpy(new_w, new_h, new_k, kind_tables)
            old_c = _bin_costs_kinds_numpy(old_w, old_h, old_k, kind_tables)
        else:
            per_bin = _bin_costs_numpy(W, H, modes)
            new_c = _bin_costs_numpy(new_w, new_h, modes)
            old_c = _bin_costs_numpy(old_w, old_h, modes)
        totals = per_bin.sum(axis=-1).astype(np.float64)
        return totals, np.sum(new_c - old_c, axis=-1)
    import jax.numpy as jnp

    if backend == "ref":
        if hetero:
            from .ref import portfolio_step_kinds_ref

            totals, deltas = _jit_ref_kinds()(
                jnp.asarray(W), jnp.asarray(H), jnp.asarray(kinds),
                jnp.asarray(old_w), jnp.asarray(old_h), jnp.asarray(old_k),
                jnp.asarray(new_w), jnp.asarray(new_h), jnp.asarray(new_k),
                kind_tables,
            )
        else:
            totals, deltas = _jit_ref()(
                jnp.asarray(W), jnp.asarray(H),
                jnp.asarray(old_w), jnp.asarray(old_h),
                jnp.asarray(new_w), jnp.asarray(new_h), modes,
            )
    elif backend == "pallas":
        if hetero:
            from .kernel import portfolio_step_kinds_pallas

            totals, deltas = portfolio_step_kinds_pallas(
                jnp.asarray(W), jnp.asarray(H), jnp.asarray(kinds),
                jnp.asarray(old_w), jnp.asarray(old_h), jnp.asarray(old_k),
                jnp.asarray(new_w), jnp.asarray(new_h), jnp.asarray(new_k),
                kind_tables, interpret,
            )
        else:
            from .kernel import portfolio_step_pallas

            totals, deltas = portfolio_step_pallas(
                jnp.asarray(W), jnp.asarray(H),
                jnp.asarray(old_w), jnp.asarray(old_h),
                jnp.asarray(new_w), jnp.asarray(new_h), modes, interpret,
            )
    else:
        raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
    return (
        np.asarray(totals, dtype=np.float64),
        np.asarray(deltas, dtype=np.int64),
    )


_SHARD_CACHE: dict = {}


def _portfolio_step_sharded(
    W, H, old_w, old_h, new_w, new_h, modes, backend, interpret,
    kinds, old_k, new_k, kind_tables, mesh,
) -> tuple[np.ndarray, np.ndarray]:
    """Row-sharded fused step over the ``("prob",)`` mesh (PR 8).

    The two halves carry different row counts (GA population stacks vs SA
    touched-bin rows), so each pads to a mesh-size multiple independently;
    one shard_map program still evaluates both.
    """
    import jax.numpy as jnp

    from repro.kernels.probshard import mesh_size, pad_rows, row_shard

    k = mesh_size(mesh)
    hetero = kind_tables is not None
    if hetero:
        key = (mesh, backend, interpret, kind_tables)
    else:
        key = (mesh, backend, interpret, modes)
    fn = _SHARD_CACHE.get(key)
    if fn is None:
        if backend == "ref":
            from .ref import portfolio_step_kinds_ref, portfolio_step_ref

            if hetero:
                def body(w, h, kk, ow, oh, ok, nw, nh, nk):
                    return portfolio_step_kinds_ref(
                        w, h, kk, ow, oh, ok, nw, nh, nk, kind_tables
                    )
            else:
                def body(w, h, ow, oh, nw, nh):
                    return portfolio_step_ref(w, h, ow, oh, nw, nh, modes)
        else:
            from .kernel import (
                portfolio_step_kinds_pallas,
                portfolio_step_pallas,
            )

            if hetero:
                def body(w, h, kk, ow, oh, ok, nw, nh, nk):
                    return portfolio_step_kinds_pallas(
                        w, h, kk, ow, oh, ok, nw, nh, nk, kind_tables,
                        interpret,
                    )
            else:
                def body(w, h, ow, oh, nw, nh):
                    return portfolio_step_pallas(
                        w, h, ow, oh, nw, nh, modes, interpret
                    )
        fn = _SHARD_CACHE[key] = row_shard(mesh, body, n_outputs=2)
    pop = (W, H) + ((kinds,) if hetero else ())
    step = (
        (old_w, old_h, old_k, new_w, new_h, new_k)
        if hetero
        else (old_w, old_h, new_w, new_h)
    )
    pop, n_pop = pad_rows(pop, k)
    step, n_step = pad_rows(step, k)
    if hetero:
        w, h, kk = pop
        ow, oh, ok, nw, nh, nk = step
        args = (w, h, kk, ow, oh, ok, nw, nh, nk)
    else:
        w, h = pop
        ow, oh, nw, nh = step
        args = (w, h, ow, oh, nw, nh)
    totals, deltas = fn(*(jnp.asarray(a) for a in args))
    return (
        np.asarray(totals[:n_pop], dtype=np.float64),
        np.asarray(deltas[:n_step], dtype=np.int64),
    )


_REF_JIT = None
_REF_KINDS_JIT = None


def _jit_ref():
    global _REF_JIT
    if _REF_JIT is None:
        import functools

        import jax

        from .ref import portfolio_step_ref

        _REF_JIT = functools.partial(jax.jit, static_argnames=("modes",))(
            portfolio_step_ref
        )
    return _REF_JIT


def _jit_ref_kinds():
    global _REF_KINDS_JIT
    if _REF_KINDS_JIT is None:
        import functools

        import jax

        from .ref import portfolio_step_kinds_ref

        _REF_KINDS_JIT = functools.partial(
            jax.jit, static_argnames=("kind_tables",)
        )(portfolio_step_kinds_ref)
    return _REF_KINDS_JIT


def resolve_auto() -> tuple[str, bool]:
    """The fused-step "auto" policy: the Pallas composition on a real TPU,
    the jit'd reference elsewhere.  (The portfolio only routes barriers
    through the fused path when BOTH engine backends are jax-resolved, so
    on a CPU host — where SA auto-resolves to host numpy — fused dispatch
    stays off and this policy never demotes the hot path.)"""
    try:
        import jax

        if jax.default_backend() == "tpu":
            return "pallas", False
    except Exception:
        pass
    return "ref", True
