"""Pure-jnp oracle for the fused portfolio step.

One traced function computes the GA side (per-individual population totals)
and the SA side (per-chain delta costs) together, so a jit of either wrapper
in ``ops.py`` compiles ONE combined XLA program per barrier segment instead
of two separate dispatches.  Both halves reuse the exact-integer cost
primitives of ``binpack_fitness`` / ``binpack_sa_step``, so results are
bit-identical to the separate calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.binpack_fitness.ref import (
    binpack_fitness_kinds_ref,
    binpack_fitness_ref,
)
from repro.kernels.binpack_sa_step.ref import (
    sa_step_deltas_kinds_ref,
    sa_step_deltas_ref,
)


def portfolio_step_ref(
    W: jax.Array,  # (..., NB) int32 — stacked GA population geometry
    H: jax.Array,
    old_w: jax.Array,  # (R, T) int32 — SA touched-bin geometry before
    old_h: jax.Array,
    new_w: jax.Array,  # (R, T) int32 — SA touched-bin geometry after
    new_h: jax.Array,
    modes: tuple[tuple[int, int], ...],
) -> tuple[jax.Array, jax.Array]:
    """-> ((...,) population totals, (R,) SA delta costs), both exact ints."""
    nb = W.shape[-1]
    per_bin = binpack_fitness_ref(W.reshape(-1, nb), H.reshape(-1, nb), modes)
    totals = jnp.sum(per_bin, axis=1).reshape(W.shape[:-1])
    deltas = sa_step_deltas_ref(old_w, old_h, new_w, new_h, modes)
    return totals, deltas


def portfolio_step_kinds_ref(
    W: jax.Array,
    H: jax.Array,
    Km: jax.Array,  # (..., NB) int32 RAM-kind lanes of the GA populations
    old_w: jax.Array,
    old_h: jax.Array,
    old_k: jax.Array,  # (R, T) int32 RAM-kind lanes before the SA move
    new_w: jax.Array,
    new_h: jax.Array,
    new_k: jax.Array,  # (R, T) int32 RAM-kind lanes after the SA move
    kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...],
) -> tuple[jax.Array, jax.Array]:
    """Heterogeneous variant: per-bin kind lanes select per-kind mode
    tables/weights on both the GA and the SA side."""
    nb = W.shape[-1]
    per_bin = binpack_fitness_kinds_ref(
        W.reshape(-1, nb), H.reshape(-1, nb), Km.reshape(-1, nb), kind_tables
    )
    totals = jnp.sum(per_bin, axis=1).reshape(W.shape[:-1])
    deltas = sa_step_deltas_kinds_ref(
        old_w, old_h, old_k, new_w, new_h, new_k, kind_tables
    )
    return totals, deltas
