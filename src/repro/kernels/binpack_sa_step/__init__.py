"""Fused SA step kernel: delta costs + the Metropolis rule.

`ops.sa_step_deltas` reduces padded (C, T) — or, with a leading problem
axis, (NP, C, T) — touched-bin geometry to per-chain integer cost deltas;
see docs/DESIGN.md section 10 for the batching axes and the padding/masking
contract.
"""
from .ops import metropolis_mask, sa_step_deltas  # noqa: F401
