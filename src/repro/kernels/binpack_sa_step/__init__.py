from .ops import metropolis_mask, sa_step_deltas  # noqa: F401
