"""Pallas TPU kernel: fused multi-chain SA delta-cost step.

Each annealing step proposes one buffer-swap move per chain; only the
touched bins change cost.  The kernel evaluates, for every chain at once,

    d_e(chain) = sum_b cost(new_b) - cost(old_b),
    cost(w, h) = min_m ceil(w / w_m) * ceil(h / d_m)

over the BRAM aspect modes — pure integer VPU work with the per-chain
reduction fused into the same program, so one step is a single kernel
launch regardless of the chain count.

Layout: four (C, T) int32 matrices (old/new width/height of the touched
bins), T padded to a lane multiple and C to the sublane tile; empty slots
carry w = h = 0 and contribute nothing.  The grid tiles the chains; each
program reduces a (CHAIN_TILE, T) block to a (CHAIN_TILE, 1) delta column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the per-kind cost block is shared with the fitness kernel so the two
# Pallas bodies can never drift apart arithmetically
from repro.kernels.binpack_fitness.kernel import kind_cost_block

CHAIN_TILE = 8  # chain rows per program (sublane tile for int32)


def _sa_step_kernel(ow_ref, oh_ref, nw_ref, nh_ref, d_ref, *, modes):
    def bin_cost(w, h):
        best = jnp.full(w.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
        for mw, md in modes:
            c = ((w + (mw - 1)) // mw) * ((h + (md - 1)) // md)
            best = jnp.minimum(best, c)
        # empty slots (w == 0) cost nothing
        return jnp.where(w > 0, best, 0)

    delta = bin_cost(nw_ref[...], nh_ref[...]) - bin_cost(ow_ref[...], oh_ref[...])
    d_ref[...] = jnp.sum(delta, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("modes", "interpret"))
def sa_step_deltas_pallas(
    old_w: jax.Array,  # (C, T) int32
    old_h: jax.Array,
    new_w: jax.Array,
    new_h: jax.Array,
    modes: tuple[tuple[int, int], ...],
    interpret: bool = True,  # CPU host: validate via interpreter
) -> jax.Array:
    c, t = old_w.shape
    pad_c = (-c) % CHAIN_TILE
    pad_t = (-t) % 128
    if pad_c or pad_t:
        pad = ((0, pad_c), (0, pad_t))
        old_w, old_h, new_w, new_h = (
            jnp.pad(x, pad) for x in (old_w, old_h, new_w, new_h)
        )
    cp, tp = old_w.shape
    out = pl.pallas_call(
        functools.partial(_sa_step_kernel, modes=modes),
        grid=(cp // CHAIN_TILE,),
        in_specs=[pl.BlockSpec((CHAIN_TILE, tp), lambda i: (i, 0))] * 4,
        out_specs=pl.BlockSpec((CHAIN_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, 1), jnp.int32),
        interpret=interpret,
    )(old_w, old_h, new_w, new_h)
    return out[:c, 0]


def _sa_step_kinds_kernel(
    ow_ref, oh_ref, ok_ref, nw_ref, nh_ref, nk_ref, d_ref, *, kind_tables
):
    delta = kind_cost_block(
        nw_ref[...], nh_ref[...], nk_ref[...], kind_tables
    ) - kind_cost_block(ow_ref[...], oh_ref[...], ok_ref[...], kind_tables)
    d_ref[...] = jnp.sum(delta, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("kind_tables", "interpret"))
def sa_step_deltas_kinds_pallas(
    old_w: jax.Array,  # (C, T) int32
    old_h: jax.Array,
    old_k: jax.Array,  # (C, T) int32 RAM-kind indices
    new_w: jax.Array,
    new_h: jax.Array,
    new_k: jax.Array,
    kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...],
    interpret: bool = True,  # CPU host: validate via interpreter
) -> jax.Array:
    """Heterogeneous fused delta step: per-slot kind lanes select the mode
    table and unit weight (same tiling as the homogeneous kernel)."""
    c, t = old_w.shape
    pad_c = (-c) % CHAIN_TILE
    pad_t = (-t) % 128
    args = (old_w, old_h, old_k, new_w, new_h, new_k)
    if pad_c or pad_t:
        pad = ((0, pad_c), (0, pad_t))
        args = tuple(jnp.pad(x, pad) for x in args)
    cp, tp = args[0].shape
    out = pl.pallas_call(
        functools.partial(_sa_step_kinds_kernel, kind_tables=kind_tables),
        grid=(cp // CHAIN_TILE,),
        in_specs=[pl.BlockSpec((CHAIN_TILE, tp), lambda i: (i, 0))] * 6,
        out_specs=pl.BlockSpec((CHAIN_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, 1), jnp.int32),
        interpret=interpret,
    )(*args)
    return out[:c, 0]
