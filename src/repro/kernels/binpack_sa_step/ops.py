"""Dispatcher for the fused SA step: per-chain delta cost + Metropolis rule.

``sa_step_deltas`` is the hot primitive of the batched multi-chain annealer:
four padded (C, T) int32 matrices (touched-bin geometry before/after one
buffer-swap move per chain) reduce to a (C,) integer delta-cost vector in a
single call.  Backends:

* ``"python"`` — vectorized numpy; no JAX import on the hot path.  At SA's
  tiny per-step shapes (T = 2 * swap_moves) this is the fastest option on a
  CPU host, where per-call device dispatch would dominate.
* ``"ref"`` — jit'd pure-jnp oracle (one fused XLA computation per step).
* ``"pallas"`` — the Pallas TPU kernel (interpreter-validated off-TPU).
* ``"auto"`` — ``pallas`` when a TPU is attached, else ``python``.

All backends use exact integer arithmetic and return bit-identical deltas;
the annealer's trajectory therefore cannot depend on the backend choice.

The Metropolis *comparison* (``u < exp(-d_e / T)``) deliberately stays
host-side in float64 (`metropolis_mask`, or a conditional scalar draw in the
single-chain engine): the legacy scalar loop draws its uniform only for
uphill moves and compares against ``math.exp``, and the engine's
backend-bit-parity contract pins that exact stream and rounding.  Fusing the
compare into the f32 kernel would break parity for ~1-ulp boundary cases.
"""
from __future__ import annotations

import numpy as np

from repro.core.problem import BRAM18_MODES

BACKENDS = ("auto", "python", "ref", "pallas")


def _bin_costs_numpy(w: np.ndarray, h: np.ndarray, modes) -> np.ndarray:
    w = np.asarray(w, dtype=np.int64)[..., None]
    h = np.asarray(h, dtype=np.int64)[..., None]
    mode_w = np.asarray([m[0] for m in modes], dtype=np.int64)
    mode_d = np.asarray([m[1] for m in modes], dtype=np.int64)
    per_mode = -(-w // mode_w) * -(-h // mode_d)  # ceil div
    return np.where(w[..., 0] > 0, np.min(per_mode, axis=-1), 0)


def _bin_costs_kinds_numpy(w, h, k, kind_tables) -> np.ndarray:
    """Per-slot unit cost with a RAM-kind lane selecting the mode table."""
    k = np.asarray(k)
    out = np.zeros(np.asarray(w).shape, dtype=np.int64)
    for ki, (weight, modes) in enumerate(kind_tables):
        out = np.where(k == ki, _bin_costs_numpy(w, h, modes) * int(weight), out)
    return out


def sa_step_deltas(
    old_w,
    old_h,
    new_w,
    new_h,
    modes=BRAM18_MODES,
    backend: str = "auto",
    interpret: bool = True,
    old_k=None,
    new_k=None,
    kind_tables=None,
    mesh=None,
) -> np.ndarray:
    """(C, T) touched-bin geometry before/after -> (C,) int64 cost deltas.

    Empty slots (w == 0) cost nothing on either side, so rows may be
    zero-padded to a common touched-bin count.  Heterogeneous problems pass
    per-slot RAM-kind lanes ``old_k``/``new_k`` plus the problem's
    ``kind_tables`` (``(weight, modes)`` per kind): each slot is then costed
    on its own mode table, so a kind flip (same geometry, different kind) is
    just another delta.  All backends stay exact-integer and bit-identical.

    A leading *problem axis* is also accepted on every backend:
    ``(NP, C, T)`` inputs return ``(NP, C)`` deltas — one fused call for a
    fleet of padded problems' chain blocks (the DSE sweep path —
    docs/DESIGN.md section 10).  Padded problems are masked by the same
    zero-width convention as padded slots.

    ``mesh`` (a 1-D ``("prob",)`` mesh from ``launch.mesh.make_sweep_mesh``)
    row-shards the jax backends via ``shard_map``: rows zero-pad to a
    multiple of the mesh size and each device costs its contiguous block,
    bit-identically (exact integers — docs/DESIGN.md section 14).  The
    ``"python"`` backend is host numpy — single-device by nature — so it
    ignores ``mesh``.
    """
    if backend == "auto":
        backend, interpret = resolve_auto()
    if np.ndim(old_w) == 3:
        np_, c_, t_ = np.shape(old_w)
        flat = lambda a: None if a is None else np.reshape(np.asarray(a), (np_ * c_, t_))  # noqa: E731
        out = sa_step_deltas(
            flat(old_w), flat(old_h), flat(new_w), flat(new_h),
            modes=modes, backend=backend, interpret=interpret,
            old_k=flat(old_k), new_k=flat(new_k), kind_tables=kind_tables,
            mesh=mesh,
        )
        return out.reshape(np_, c_)
    hetero = old_k is not None
    if hetero:
        if new_k is None or kind_tables is None:
            raise ValueError("old_k/new_k/kind_tables must be passed together")
        kind_tables = tuple((int(w), tuple(m)) for w, m in kind_tables)
    if mesh is not None and backend in ("ref", "pallas"):
        return _sa_step_deltas_sharded(
            old_w, old_h, new_w, new_h, modes, backend, interpret,
            old_k, new_k, kind_tables, mesh,
        )
    if backend == "python":
        if hetero:
            new_c = _bin_costs_kinds_numpy(new_w, new_h, new_k, kind_tables)
            old_c = _bin_costs_kinds_numpy(old_w, old_h, old_k, kind_tables)
        else:
            new_c = _bin_costs_numpy(new_w, new_h, modes)
            old_c = _bin_costs_numpy(old_w, old_h, modes)
        return np.sum(new_c - old_c, axis=-1)
    import jax.numpy as jnp

    if backend == "ref":
        if hetero:
            out = _jit_ref_kinds()(
                jnp.asarray(old_w), jnp.asarray(old_h), jnp.asarray(old_k),
                jnp.asarray(new_w), jnp.asarray(new_h), jnp.asarray(new_k),
                kind_tables,
            )
        else:
            out = _jit_ref()(
                jnp.asarray(old_w), jnp.asarray(old_h),
                jnp.asarray(new_w), jnp.asarray(new_h), tuple(modes),
            )
    elif backend == "pallas":
        if hetero:
            from .kernel import sa_step_deltas_kinds_pallas

            out = sa_step_deltas_kinds_pallas(
                jnp.asarray(old_w), jnp.asarray(old_h), jnp.asarray(old_k),
                jnp.asarray(new_w), jnp.asarray(new_h), jnp.asarray(new_k),
                kind_tables, interpret,
            )
        else:
            from .kernel import sa_step_deltas_pallas

            out = sa_step_deltas_pallas(
                jnp.asarray(old_w), jnp.asarray(old_h),
                jnp.asarray(new_w), jnp.asarray(new_h), tuple(modes), interpret,
            )
    else:
        raise ValueError(f"unknown backend {backend!r}; options: {BACKENDS}")
    return np.asarray(out, dtype=np.int64)


_SHARD_CACHE: dict = {}


def _sa_step_deltas_sharded(
    old_w, old_h, new_w, new_h, modes, backend, interpret,
    old_k, new_k, kind_tables, mesh,
) -> np.ndarray:
    """Row-sharded delta evaluation over the ``("prob",)`` mesh (PR 8)."""
    import jax.numpy as jnp

    from repro.kernels.probshard import mesh_size, pad_rows, row_shard

    k = mesh_size(mesh)
    hetero = old_k is not None
    if hetero:
        key = (mesh, backend, interpret, kind_tables)
    else:
        modes = tuple(modes)
        key = (mesh, backend, interpret, modes)
    fn = _SHARD_CACHE.get(key)
    if fn is None:
        if backend == "ref":
            from .ref import sa_step_deltas_kinds_ref, sa_step_deltas_ref

            if hetero:
                def body(ow, oh, ok, nw, nh, nk):
                    return sa_step_deltas_kinds_ref(
                        ow, oh, ok, nw, nh, nk, kind_tables
                    )
            else:
                def body(ow, oh, nw, nh):
                    return sa_step_deltas_ref(ow, oh, nw, nh, modes)
        else:
            from .kernel import (
                sa_step_deltas_kinds_pallas,
                sa_step_deltas_pallas,
            )

            if hetero:
                def body(ow, oh, ok, nw, nh, nk):
                    return sa_step_deltas_kinds_pallas(
                        ow, oh, ok, nw, nh, nk, kind_tables, interpret
                    )
            else:
                def body(ow, oh, nw, nh):
                    return sa_step_deltas_pallas(
                        ow, oh, nw, nh, modes, interpret
                    )
        fn = _SHARD_CACHE[key] = row_shard(mesh, body)
    if hetero:
        args = (old_w, old_h, old_k, new_w, new_h, new_k)
    else:
        args = (old_w, old_h, new_w, new_h)
    args, n = pad_rows(args, k)
    out = fn(*(jnp.asarray(a) for a in args))
    return np.asarray(out[:n], dtype=np.int64)


def metropolis_mask(d_e, temps, u) -> np.ndarray:
    """Vectorized Metropolis rule: accept downhill, else ``u < exp(-d/T)``.

    Float64 throughout, matching the scalar loop's ``math.exp`` comparison.
    ``T <= 0`` freezes uphill moves entirely (greedy descent).
    """
    d = np.asarray(d_e, dtype=np.float64)
    t = np.asarray(temps, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    safe_t = np.where(t > 0, t, 1.0)
    p = np.exp(-np.maximum(d, 0.0) / safe_t)
    return (d < 0) | ((t > 0) & (u < p))


_REF_JIT = None
_REF_KINDS_JIT = None


def _jit_ref():
    global _REF_JIT
    if _REF_JIT is None:
        import functools

        import jax

        from .ref import sa_step_deltas_ref

        _REF_JIT = functools.partial(jax.jit, static_argnames=("modes",))(
            sa_step_deltas_ref
        )
    return _REF_JIT


def _jit_ref_kinds():
    global _REF_KINDS_JIT
    if _REF_KINDS_JIT is None:
        import functools

        import jax

        from .ref import sa_step_deltas_kinds_ref

        _REF_KINDS_JIT = functools.partial(
            jax.jit, static_argnames=("kind_tables",)
        )(sa_step_deltas_kinds_ref)
    return _REF_KINDS_JIT


def resolve_auto() -> tuple[str, bool]:
    """The SA "auto" policy: (backend, interpret) — the Pallas kernel on a
    real TPU, host numpy everywhere else (per-step shapes are too small for
    CPU device dispatch to pay off)."""
    try:
        import jax

        if jax.default_backend() == "tpu":
            return "pallas", False
    except Exception:
        pass
    return "python", True
