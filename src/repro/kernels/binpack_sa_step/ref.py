"""Pure-jnp oracle for the fused SA delta-cost step.

Reuses the binpack fitness cost primitive: the delta of one annealing move is
the cost difference of the touched bins before/after, summed per chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.binpack_fitness.ref import (
    binpack_fitness_kinds_ref,
    binpack_fitness_ref,
)


def sa_step_deltas_ref(
    old_w: jax.Array,  # (C, T) int32 — touched-bin geometry before the move
    old_h: jax.Array,
    new_w: jax.Array,  # (C, T) int32 — geometry after the move (0 = no bin)
    new_h: jax.Array,
    modes: tuple[tuple[int, int], ...],
) -> jax.Array:
    """(C,) int32 total BRAM-cost delta per chain."""
    new_cost = binpack_fitness_ref(new_w, new_h, modes)
    old_cost = binpack_fitness_ref(old_w, old_h, modes)
    return jnp.sum(new_cost - old_cost, axis=1)


def sa_step_deltas_kinds_ref(
    old_w: jax.Array,
    old_h: jax.Array,
    old_k: jax.Array,  # (C, T) int32 RAM-kind indices before the move
    new_w: jax.Array,
    new_h: jax.Array,
    new_k: jax.Array,  # (C, T) int32 RAM-kind indices after the move
    kind_tables: tuple[tuple[int, tuple[tuple[int, int], ...]], ...],
) -> jax.Array:
    """Heterogeneous variant: kind lanes select per-bin mode tables/weights
    (a RAM-kind flip is a delta with equal geometry and different kinds)."""
    new_cost = binpack_fitness_kinds_ref(new_w, new_h, new_k, kind_tables)
    old_cost = binpack_fitness_kinds_ref(old_w, old_h, old_k, kind_tables)
    return jnp.sum(new_cost - old_cost, axis=1)
