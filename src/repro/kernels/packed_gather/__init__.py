from .kernel import packed_gather_matvec  # noqa: F401
from .ops import bank_matvec, split_outputs  # noqa: F401
from .ref import packed_gather_ref  # noqa: F401
