"""Pallas TPU kernel: fused packed-bank read + MAC (segment matvec).

The inference-side analogue of the paper's multi-port BRAM bins: several
logical weight matrices are co-located row-wise in one physical bank
(rows % sublane == 0, cols % 128 == 0).  One kernel pass streams the bank
HBM->VMEM once and computes every co-located logical output:

    y[r] = sum_c bank[r, c] * x[seg[r], c]

where seg[r] names which logical buffer row r belongs to (cardinality <= C
descriptors per bank, the paper's port constraint).  Without packing, each
logical buffer would be a separate (padded) array and a separate DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8  # fp32 sublane tile


def _packed_gather_kernel(bank_ref, x_ref, seg_ref, y_ref, *, n_logical):
    bank = bank_ref[...]  # (TR, C)
    seg = seg_ref[...]  # (TR, 1) int32
    acc = jnp.zeros(bank.shape[:1] + (1,), jnp.float32)
    for n in range(n_logical):  # cardinality-bounded unrolled loop
        xn = x_ref[n, :]  # (C,)
        partial = jnp.sum(bank * xn[None, :], axis=1, keepdims=True)
        acc = jnp.where(seg == n, partial, acc)
    y_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def packed_gather_matvec(
    bank: jax.Array,  # (R, C) f32, R % 8 == 0, C % 128 == 0
    x: jax.Array,  # (N, C) f32 — one activation vector per logical buffer
    seg: jax.Array,  # (R,) int32 segment ids in [0, N)
    interpret: bool = True,
) -> jax.Array:
    r, c = bank.shape
    n = x.shape[0]
    seg2 = seg.astype(jnp.int32).reshape(r, 1)
    out = pl.pallas_call(
        functools.partial(_packed_gather_kernel, n_logical=n),
        grid=(r // ROW_TILE,),
        in_specs=[
            pl.BlockSpec((ROW_TILE, c), lambda i: (i, 0)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
            pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=interpret,
    )(bank, x, seg2)
    return out[:, 0]
