"""Jit'd wrapper: evaluate all logical matvecs of one packed bank."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import packed_gather_matvec
from .ref import packed_gather_ref


def bank_matvec(bank, x, seg, backend: str = "pallas", interpret: bool = True):
    if backend == "pallas":
        return packed_gather_matvec(bank, x, seg, interpret=interpret)
    return packed_gather_ref(bank, x, seg)


def split_outputs(y, seg, n_logical: int):
    """Scatter the fused (R,) result back into per-logical-buffer outputs."""
    return [y[jnp.asarray(seg) == n] for n in range(n_logical)]
