"""Pure-jnp oracle for the packed-bank segment matvec."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def packed_gather_ref(bank: jax.Array, x: jax.Array, seg: jax.Array) -> jax.Array:
    gathered = x[seg]  # (R, C)
    return jnp.sum(bank * gathered, axis=1)
