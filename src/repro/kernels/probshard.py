"""shard_map plumbing for the 1-D ``("prob",)`` sweep mesh (PR 8).

The three batched bin-packing kernels (``binpack_fitness``,
``binpack_sa_step``, ``binpack_portfolio_step``) are row programs: every
operand carries the fleet's problem/chain rows on its leading axis and all
rows are independent.  Sharding them across a ``launch.mesh.make_sweep_mesh``
mesh is therefore purely mechanical:

1. zero-pad each operand's leading axis to a multiple of the mesh size
   (cost-neutral by the zero-width masking contract of DESIGN.md section 10
   — a padded row has width 0 everywhere and contributes cost 0),
2. run the kernel body under ``shard_map`` with every operand row-sharded
   over ``"prob"`` (``sharding.rules.prob_axis_spec``) so each device costs
   its own contiguous row block,
3. slice the padding back off the row-major outputs.

All kernels use exact integer arithmetic, so the sharded result is
bit-identical to the unsharded one — pinned in ``tests/test_sharded.py``.

Compiled sharded callables are cached per (mesh, static-config) key by the
ops modules; this module only holds the shared padding/wrapping helpers so
the jit caches stay hot across the annealer's per-iteration calls.
"""
from __future__ import annotations

import numpy as np


def mesh_size(mesh) -> int:
    """Width of the ``"prob"`` axis (validates the mesh is a sweep mesh)."""
    try:
        return int(mesh.shape["prob"])
    except (KeyError, TypeError) as e:
        raise ValueError(
            "mesh= must be a 1-D ('prob',) sweep mesh "
            "(launch.mesh.make_sweep_mesh); got axes "
            f"{getattr(mesh, 'axis_names', mesh)!r}"
        ) from e


def pad_rows(arrays, k: int):
    """Zero-pad each array's leading axis to a multiple of ``k`` rows.

    Returns ``(padded, n)`` where ``n`` is the original row count; callers
    slice outputs back with ``out[:n]``.  Zero rows are cost-free under the
    zero-width masking contract, so padding never perturbs results.
    """
    ns = {np.shape(a)[0] for a in arrays if a is not None}
    if len(ns) != 1:
        raise ValueError(f"operands disagree on row count: {sorted(ns)}")
    (n,) = ns
    pad = (-n) % k
    if pad == 0:
        return tuple(arrays), n
    out = []
    for a in arrays:
        if a is None:
            out.append(None)
            continue
        a = np.asarray(a)
        block = np.zeros((pad,) + a.shape[1:], dtype=a.dtype)
        out.append(np.concatenate([a, block], axis=0))
    return tuple(out), n


def row_shard(mesh, fn, n_outputs: int = 1):
    """Wrap ``fn(*row_arrays)`` in jit(shard_map) over the ``"prob"`` axis.

    Every positional input and every output is row-sharded on its leading
    axis; trailing axes are replicated.  ``fn`` must close over its static
    configuration (mode tables, interpret flag) — callers cache the wrapped
    function per static key so jit compiles once per configuration.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import prob_axis_spec

    def run(*arrays):
        in_specs = tuple(prob_axis_spec(a.ndim) for a in arrays)
        if n_outputs == 1:
            out_specs = P("prob")
        else:
            out_specs = tuple(P("prob") for _ in range(n_outputs))
        # check_rep=False: jax has no replication rule for pallas_call, and
        # nothing here relies on replication checking (every operand and
        # output is explicitly row-sharded or replicated).
        body = shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
        return body(*arrays)

    return jax.jit(run)
