"""Batched decode demo: prefill a batch of prompts, decode N tokens.

(Formerly ``launch/serve.py``; renamed so the name is free for the real
packing service in ``repro.serve``.)

``--packed`` routes the weights through the paper's memory packer
(PackedParameterStore): banks are planned with GA-NFD, materialized, and
the model consumes ``store.unpack()`` views — demonstrating the packed
parameter path end-to-end with identical outputs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.train import scaled_config
from repro.memory import PackedParameterStore, plan_packing
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = scaled_config(args)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    if args.packed:
        plans = plan_packing(params, max_seconds=3.0, split_stacked=True)
        store = PackedParameterStore(params, plans)
        for isz, s in store.stats().items():
            print(
                f"packed itemsize={isz}: {s['packed_tensors']} tensors in "
                f"{s['banks']} banks, eff {s['efficiency_before']:.3f} -> "
                f"{s['efficiency_after']:.3f} (saved {s['saved_bytes']} bytes)"
            )
        params = store.unpack()

    b, p_len, g_len = args.batch, args.prompt_len, args.gen_len
    cache_len = p_len + g_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab_size, (b, p_len)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)) * 0.02, jnp.float32
        )
        cache_len += cfg.num_patches
    if cfg.encoder_decoder:
        batch = {
            "frames": jnp.asarray(
                rng.normal(size=(b, p_len, cfg.d_model)) * 0.02, jnp.float32
            ),
            "tokens": prompts[:, :4],
        }

    prefill = jax.jit(lambda p, bt: M.prefill(cfg, p, bt, cache_len))
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    pos0 = batch["tokens"].shape[1] + (cfg.num_patches if "patches" in batch else 0)
    for i in range(g_len - 1):
        cache, logits = decode(params, cache, tok, jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"generated {gen.shape} in {dt:.2f}s ({b * g_len / dt:.1f} tok/s)")
    print("first row:", gen[0][:12], "...")
    return gen


if __name__ == "__main__":
    main()
