import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

# ruff: noqa: E402  (the XLA flag MUST precede any jax-importing module)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (a) the sharding config is coherent on the
production mesh (compile succeeds, no sharding mismatch / unsupported
collective), (b) it fits (memory_analysis), and records (c) the roofline
terms (cost_analysis FLOPs/bytes + collective bytes parsed from the
optimized HLO).  Results are cached as JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--single-pod] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_config, shape_cells
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    cache_specs,
    input_specs,
    opt_specs,
    param_specs,
)
from repro.models.config import SHAPES
from repro.optim import AdamWConfig
from repro.runtime import TrainState, make_decode_step, make_prefill_step, make_train_step
from repro.sharding import (
    batch_partition_specs,
    cache_partition_specs,
    opt_partition_specs,
    param_partition_specs,
    to_named,
)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _attach(shardings, structs):
    """Rebuild ShapeDtypeStructs with NamedShardings attached."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        structs,
        shardings,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (lowered, compiled, meta) for one cell."""
    import dataclasses as _dc

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind != "train":
        # serving profile: bf16 weights (production practice; halves the
        # weight-read term that dominates decode)
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    p_struct = param_specs(cfg)
    p_shard = to_named(mesh, param_partition_specs(cfg, mesh, p_struct))
    specs = input_specs(cfg, shape_name)

    with mesh:
        if shape.kind == "train":
            o_struct = opt_specs(p_struct)
            o_shard = to_named(mesh, opt_partition_specs(cfg, mesh, o_struct))
            b_shard = to_named(mesh, batch_partition_specs(cfg, mesh, specs["batch"]))
            state = TrainState(_attach(p_shard, p_struct), _attach(o_shard, o_struct))
            batch = _attach(b_shard, specs["batch"])
            step = make_train_step(cfg, AdamWConfig())
            jitted = jax.jit(
                step,
                out_shardings=(TrainState(p_shard, o_shard), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            b_shard = to_named(mesh, batch_partition_specs(cfg, mesh, specs["batch"]))
            batch = _attach(b_shard, specs["batch"])
            c_struct = cache_specs(cfg, shape)
            c_shard = to_named(mesh, cache_partition_specs(cfg, mesh, c_struct))
            step = make_prefill_step(cfg, shape.seq_len)
            jitted = jax.jit(step, out_shardings=(c_shard, None))
            lowered = jitted.lower(_attach(p_shard, p_struct), batch)
        else:  # decode
            c_struct = specs["cache"]
            c_shard = to_named(mesh, cache_partition_specs(cfg, mesh, c_struct))
            cache = _attach(c_shard, c_struct)
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step, out_shardings=(c_shard, None), donate_argnums=(1,)
            )
            lowered = jitted.lower(
                _attach(p_shard, p_struct), cache, specs["token"], specs["pos"]
            )
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    return lowered, compiled, dict(
        arch=arch, shape=shape_name, multi_pod=multi_pod, n_devices=n_dev,
        kind=shape.kind, compile_s=compile_s,
    )


def _model_flops(cfg, shape, n_params_total: int, n_params_active: int) -> float:
    """Analytic useful-FLOPs (the 6ND / 2ND accounting), global."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        # encoder runs B*S tokens, decoder B*T tokens; halve params per stack
        n_half = n_params_active / 2
        t = min(448, cfg.max_target_len)
        fwd = 2 * n_half * b * s + 2 * n_half * b * t
        return 3 * fwd if shape.kind == "train" else (
            fwd if shape.kind == "prefill" else 2 * n_half * b
        )
    tokens = b * s
    if shape.kind == "train":
        return 6 * n_params_active * tokens
    if shape.kind == "prefill":
        return 2 * n_params_active * tokens
    return 2 * n_params_active * b  # decode: one token per sequence


def analyze(lowered, compiled, meta: dict, cfg=None, shape=None, p_struct=None) -> dict:
    xla_cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)  # trip-count aware
    # memory-term estimate: weights/args read once + each materialized tensor
    # written once and read once (perfect-fusion); cost.bytes is the
    # zero-fusion upper bound. Real TPU traffic lies between; we report both.
    arg_bytes = int(getattr(mem, "argument_size_in_bytes", 0))
    bytes_est = arg_bytes + 2.0 * cost.wbytes
    terms = roofline_terms(cost.flops, bytes_est, cost.coll_bytes)
    out = dict(meta)
    out.update(
        flops_per_device=cost.flops,
        bytes_per_device=bytes_est,
        bytes_upper_bound=cost.bytes,
        bytes_write_once=cost.wbytes,
        collective_operand_bytes=int(cost.coll_bytes),
        collectives_by_op={k: list(v) for k, v in cost.coll_by_op.items()},
        unknown_trip_loops=cost.unknown_trip,
        xla_cost_analysis=dict(
            flops=float(xla_cost.get("flops", 0.0)),
            bytes_accessed=float(xla_cost.get("bytes accessed", 0.0)),
        ),
        roofline=terms,
        memory=dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            generated_code_bytes=int(getattr(mem, "generated_code_size_in_bytes", 0)),
            alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
        ),
        hlo_lines=hlo.count("\n"),
    )
    if cfg is not None and p_struct is not None:
        import numpy as _np

        n_total = int(
            sum(_np.prod(x.shape) for x in jax.tree.leaves(p_struct))
        )
        expert = (
            cfg.n_layers * cfg.n_experts * (3 if cfg.mlp_gated else 2)
            * cfg.d_model * cfg.d_ff
            if cfg.n_experts
            else 0
        )
        active_expert = (
            cfg.n_layers * cfg.top_k * (3 if cfg.mlp_gated else 2)
            * cfg.d_model * cfg.d_ff * cfg.capacity_factor
            if cfg.n_experts
            else 0
        )
        n_active = n_total - expert + active_expert
        mf = _model_flops(cfg, shape, n_total, n_active)
        hlo_flops_global = cost.flops * meta["n_devices"]
        out.update(
            n_params=n_total,
            n_params_active=int(n_active),
            model_flops_global=mf,
            useful_flops_ratio=(mf / hlo_flops_global) if hlo_flops_global else 0.0,
        )
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False) -> dict:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    path = OUT_DIR / f"{tag}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod)
        cfg = get_config(arch)
        result = analyze(
            lowered, compiled, meta,
            cfg=cfg, shape=SHAPES[shape_name], p_struct=param_specs(cfg),
        )
        result["status"] = "ok"
    except Exception as e:  # record failures: they are bugs to fix
        result = dict(
            arch=arch, shape=shape_name, multi_pod=multi_pod,
            status="error", error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    path.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.insert(0, False)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCHS:
            for shape in shape_cells(arch):
                for mp in pods:
                    cells.append((arch, shape, mp))
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        for arch in archs:
            shapes = [args.shape] if args.shape else shape_cells(arch)
            for shape in shapes:
                for mp in pods:
                    cells.append((arch, shape, mp))

    n_ok = 0
    for arch, shape, mp in cells:
        t0 = time.perf_counter()
        r = run_cell(arch, shape, mp, force=args.force)
        dt = time.perf_counter() - t0
        status = r.get("status")
        if status == "ok":
            n_ok += 1
            terms = r["roofline"]
            print(
                f"[OK ] {arch:22s} {shape:12s} pods={2 if mp else 1} "
                f"compile={r['compile_s']:.0f}s "
                f"compute={terms['compute_s']:.3e}s mem={terms['memory_s']:.3e}s "
                f"coll={terms['collective_s']:.3e}s dom={terms['dominant']} ({dt:.0f}s)",
                flush=True,
            )
        else:
            print(f"[FAIL] {arch:22s} {shape:12s} pods={2 if mp else 1}: {r.get('error','?')[:160]}", flush=True)
    print(f"{n_ok}/{len(cells)} cells OK")


if __name__ == "__main__":
    main()
