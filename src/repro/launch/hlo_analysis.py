"""Trip-count-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` counts every while-loop *body once* — for
scan-over-layers models that undercounts FLOPs, bytes, and collective
traffic by the layer count.  This module parses the optimized HLO text into
computations and walks the call graph from ENTRY:

  * while ops multiply body+condition cost by ``known_trip_count`` (emitted
    by XLA in backend_config for counted loops; fallback 1 with a flag);
  * fusion/call/conditional recurse into callees for FLOPs;
  * dot FLOPs = 2 * |result| * |contracted dims| (from operand shapes);
  * bytes accessed are accounted at the *caller* level (operands + result of
    each top-level instruction — fusion-internal traffic is free, matching
    HloCostAnalysis semantics);
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute / ragged-all-to-all.

Also derives the three roofline terms against TPU v5e constants.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_elems: int
    lhs_dims: list[int]
    contracting: list[int]
    operand_names: list[str]
    operand_bytes: int
    calls: list[str]
    branches: list[str]
    trip: int
    raw: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0  # zero-fusion upper bound (operands + results)
    wbytes: float = 0.0  # write-once lower bound (results of real ops only)
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    unknown_trip: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wbytes += other.wbytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, (c, b) in other.coll_by_op.items():
            c0, b0 = self.coll_by_op.get(k, (0, 0))
            self.coll_by_op[k] = (c0 + c * mult, b0 + b * mult)
        self.unknown_trip += other.unknown_trip


_OPCODE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")

# ops that move no real data / pure control
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
}


def _parse_computations(hlo: str):
    """name -> list[Instr]; also returns entry computation name."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_shapes: dict[str, int] = {}
    shapes_global: dict[str, int] = {}

    header_re = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{")
    for line in hlo.splitlines():
        sline = line.strip()
        hm = header_re.match(sline)
        if hm:
            name = hm.group(2)
            comps[name] = []
            cur = comps[name]
            cur_shapes = {}
            if hm.group(1):
                entry = name
            # parameters declared in the header don't carry sizes per-name
            continue
        if sline == "}" or sline.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OPCODE_RE.match(sline)
        if not m:
            continue
        name, rhs = m.groups()
        # result type: balanced parens for tuples, else up to first space
        rhs_s = rhs.lstrip()
        if rhs_s.startswith("("):
            depth = 0
            for idx, ch in enumerate(rhs_s):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            head = rhs_s[: idx + 1]
            rest = rhs_s[idx + 1 :].lstrip()
        else:
            sp = rhs_s.find(" ")
            head = rhs_s[:sp] if sp > 0 else rhs_s
            rest = rhs_s[sp + 1 :].lstrip() if sp > 0 else ""
        opm = re.match(r"([a-z][a-z0-9\-]*)\s*\(", rest)
        op = opm.group(1) if opm else "?"
        result_bytes = _bytes_of(head)
        shp = _shapes_in(head)
        result_elems = 0
        for _, dims in shp:
            n = 1
            for d in dims:
                n *= d
            result_elems += n
        cur_shapes[name] = result_bytes
        shapes_global[name] = result_bytes
        if opm:
            close = rest.find(")", opm.end())
            args = rest[opm.end() : close] if close > 0 else ""
        else:
            args = ""
        operand_names = _NAME_RE.findall(args)
        operand_bytes = sum(
            cur_shapes.get(a, shapes_global.get(a, 0)) for a in operand_names
        )
        calls = _CALL_ATTR_RE.findall(rhs)
        branches = []
        bm = _BRANCHES_RE.search(rhs)
        if bm:
            branches = _NAME_RE.findall(bm.group(1))
        trip = 1
        tm = _TRIP_RE.search(rhs)
        if tm:
            trip = int(tm.group(1))
        lhs_dims: list[int] = []
        contracting: list[int] = []
        if op == "dot":
            cm = _CONTRACT_RE.search(rhs)
            if cm:
                contracting = [int(x) for x in cm.group(1).split(",") if x]
            # lhs shape: first shape literal in args, else lookup is lossy —
            # HLO prints operand types inline in most versions
            arg_shapes = _shapes_in(args)
            if arg_shapes:
                lhs_dims = arg_shapes[0][1]
        cur.append(
            Instr(
                name=name, op=op, result_bytes=result_bytes,
                result_elems=result_elems, lhs_dims=lhs_dims,
                contracting=contracting, operand_names=operand_names,
                operand_bytes=operand_bytes, calls=calls, branches=branches,
                trip=trip, raw=sline,
            )
        )
    return comps, entry, shapes_global


def _dot_flops(inst: Instr, dims_by_name: dict[str, list[int]]) -> float:
    lhs = inst.lhs_dims
    if not lhs and inst.operand_names:
        lhs = dims_by_name.get(inst.operand_names[0], [])
    k = 1
    for d in inst.contracting:
        if d < len(lhs):
            k *= lhs[d]
    return 2.0 * inst.result_elems * k


class HloCostModel:
    """Walks the HLO call graph with backend-artifact corrections:

    1. while bodies multiply by known_trip_count;
    2. fusions rooted in dynamic-update-slice charge the update window, not
       the full (aliased) result buffer;
    3. XLA:CPU promotes bf16 dots to f32 via *metadata-less* converts (a TPU
       backend keeps bf16); metadata-less widening converts are free, and
       tensors they produce are charged at bf16 width for the memory and
       collective terms (FLOPs are unaffected).
    """

    def __init__(self, hlo_text: str):
        self.comps, self.entry, self.sizes_global = _parse_computations(hlo_text)
        # dims of every named instruction (for dot lhs lookup fallback)
        self.dims_by_name: dict[str, list[int]] = {}
        for instrs in self.comps.values():
            for i in instrs:
                m = _shapes_in(i.raw.split("=", 1)[1].split("(", 1)[0])
                if m:
                    self.dims_by_name[i.name] = m[0][1]
        self._memo: dict[str, Cost] = {}
        self._artifact: set[str] = set()
        self._mark_artifacts()

    # -------------------------------------------------- dtype artifacts
    def _mark_artifacts(self):
        convert_comps = set()
        for cname, instrs in self.comps.items():
            real = [i for i in instrs if i.op not in _FREE_OPS]
            if (
                len(real) == 1
                and real[0].op == "convert"
                and "metadata=" not in real[0].raw
            ):
                convert_comps.add(cname)
        for instrs in self.comps.values():
            for i in instrs:
                # XLA:CPU wraps the widening convert either in a fusion or in
                # a parallel_convert `call` computation, depending on size
                widening_convert = (
                    i.op == "convert" and "metadata=" not in i.raw
                ) or (
                    i.op in ("fusion", "call")
                    and any(c in convert_comps for c in i.calls)
                )
                if widening_convert and i.operand_names:
                    opb = self.sizes_global.get(i.operand_names[0], 0)
                    if opb and i.result_bytes > opb:
                        self._artifact.add(i.name)
        # dots fed by artifact-widened operands produce artifact-f32 results
        for instrs in self.comps.values():
            for i in instrs:
                if i.op == "dot" and any(a in self._artifact for a in i.operand_names):
                    self._artifact.add(i.name)
        # propagate through same-size elementwise chains: when the largest
        # operand of an elementwise/fusion op is artifact-widened, the result
        # is too (the whole f32 region exists only because the CPU backend
        # normalized bf16 away; a TPU backend keeps the chain in bf16).
        for _ in range(8):  # fixpoint over chains
            changed = False
            for instrs in self.comps.values():
                for i in instrs:
                    if i.name in self._artifact or i.op in _FREE_OPS:
                        continue
                    if i.op in ("dot", "while", "conditional"):
                        continue
                    sizes = [
                        (self.sizes_global.get(a, 0), a) for a in i.operand_names
                    ]
                    if not sizes:
                        continue
                    big, name = max(sizes)
                    if (
                        big > 0
                        and name in self._artifact
                        and i.result_bytes >= big // 2
                    ):
                        self._artifact.add(i.name)
                        changed = True
            if not changed:
                break

    def _eff(self, name: str) -> int:
        b = self.sizes_global.get(name, 0)
        return b // 2 if name in self._artifact else b

    def _eff_result(self, inst: Instr) -> int:
        return (
            inst.result_bytes // 2 if inst.name in self._artifact else inst.result_bytes
        )

    def _eff_operands(self, inst: Instr) -> int:
        return sum(self._eff(a) for a in inst.operand_names)

    def _fusion_bytes(self, callee: str, inst: Instr) -> tuple[int, int]:
        """(read_bytes, write_bytes) of one fusion call.

        Parameters consumed through (dynamic-)slice/gather read only the
        window; a dynamic-update-slice root writes only the update window.
        Intermediates inside the fusion are free (registers/VMEM).
        """
        instrs = self.comps.get(callee, [])
        param_names: dict[str, int] = {}
        local_sizes: dict[str, int] = {}
        for i in instrs:
            local_sizes[i.name] = i.result_bytes
            if i.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.raw)
                if m:
                    param_names[i.name] = int(m.group(1))
        read = 0
        dus_write = 0
        for i in instrs:
            if i.op in ("dynamic-slice", "slice", "gather"):
                if any(a in param_names for a in i.operand_names):
                    read += i.result_bytes
                    continue
            if i.op == "dynamic-update-slice":
                upd = (
                    local_sizes.get(i.operand_names[1], 0)
                    if len(i.operand_names) > 1
                    else 0
                )
                dus_write += upd
                read += upd  # reads the update operand
                continue
            for a in i.operand_names:
                k = param_names.get(a)
                if k is not None and k < len(inst.operand_names):
                    read += self._eff(inst.operand_names[k])
        write = dus_write if dus_write else self._eff_result(inst)
        return read, write

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()  # cycle guard
        total = Cost()
        for inst in self.comps.get(comp_name, []):
            if inst.op in _FREE_OPS:
                continue
            if inst.name in self._artifact and inst.op != "dot":
                continue  # backend-inserted widening convert: free on TPU
            if inst.op == "while":
                body_cost = Cost()
                for c in inst.calls:
                    body_cost.add(self.cost_of(c))
                if inst.trip == 1 and "known_trip_count" not in inst.raw:
                    total.unknown_trip += 1
                total.add(body_cost, mult=inst.trip)
                continue  # body instructions account for all traffic
            if inst.op == "conditional":
                branch_costs = [self.cost_of(b) for b in inst.branches]
                if branch_costs:
                    worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
                total.bytes += self._eff_result(inst)
                total.wbytes += self._eff_result(inst)
                continue
            if inst.op in ("fusion", "call", "custom-call", "async-start"):
                wrote = 0
                for c in inst.calls:
                    sub = self.cost_of(c)
                    # FLOPs and collectives recurse; bytes via param-read model
                    total.flops += sub.flops
                    total.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_op.items():
                        c0, b0 = total.coll_by_op.get(k, (0, 0))
                        total.coll_by_op[k] = (c0 + v[0], b0 + v[1])
                    r, w = self._fusion_bytes(c, inst)
                    total.bytes += r + w
                    wrote += w
                if not inst.calls:
                    wrote = self._eff_result(inst)
                    total.bytes += wrote + self._eff_operands(inst)
                total.wbytes += wrote
                continue
            if inst.op == "dot":
                total.flops += _dot_flops(inst, self.dims_by_name)
                total.bytes += self._eff_operands(inst) + self._eff_result(inst)
                total.wbytes += self._eff_result(inst)
                continue
            if any(inst.op.startswith(c) or inst.op == c for c in COLLECTIVE_OPS):
                opb = self._eff_operands(inst) or self._eff_result(inst)
                base = next(
                    c for c in COLLECTIVE_OPS
                    if inst.op == c or inst.op.startswith(c)
                )
                total.coll_bytes += opb
                c0, b0 = total.coll_by_op.get(base, (0, 0))
                total.coll_by_op[base] = (c0 + 1, b0 + opb)
                total.bytes += opb + self._eff_result(inst)
                total.wbytes += self._eff_result(inst)
                continue
            if inst.op.endswith("-done"):
                continue
            if inst.op in ("dynamic-slice", "slice", "gather"):
                total.bytes += 2 * self._eff_result(inst)
                total.wbytes += self._eff_result(inst)
                continue
            if inst.op in ("dynamic-update-slice", "scatter"):
                # in-place window update: read update, read+write the window
                upd = (
                    self._eff(inst.operand_names[1])
                    if len(inst.operand_names) > 1
                    else 0
                )
                total.bytes += 3 * upd
                total.wbytes += upd
                continue
            # generic elementwise / reduce / copy: 1 flop per output element
            total.flops += inst.result_elems
            total.bytes += self._eff_operands(inst) + self._eff_result(inst)
            total.wbytes += self._eff_result(inst)
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
