"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh adds a leading "pod" axis (2 pods =
512 chips) which composes with "data" for hierarchical data parallelism —
gradient all-reduces become (pod-local reduce-scatter, cross-pod all-reduce,
pod-local all-gather) under XLA's 2-D reduction lowering, the DCN-friendly
pattern.  A "pipe" axis for pipeline stages can be added here without any
model-code change (stage = slice of the scanned layer axis); see
docs/DESIGN.md section 5 for why the deployed configuration uses pod-DP
instead.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(dryrun.py sets this automatically), or use make_host_mesh() / "
            "make_sweep_mesh(n) for CPU runs"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def make_sweep_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D ``("prob",)`` mesh sharding the fleet's problem axis (PR 8).

    The sweep/portfolio fleet (docs/DESIGN.md section 14) is a problem-major
    array program; its only shardable axis is the leading problem axis, so
    the sweep mesh is one-dimensional.  ``n_devices=None`` takes every
    visible device.  On a CPU host, multiple devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if len(devices) < n_devices:
        raise RuntimeError(
            f"sweep mesh needs {n_devices} devices but only {len(devices)} "
            "present; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            "for host-platform sharding"
        )
    return jax.make_mesh((n_devices,), ("prob",), devices=devices[:n_devices])
