"""Summarize dry-run results: per-cell roofline terms, deltas vs a baseline
snapshot, and the aggregate score table.

  PYTHONPATH=src python -m repro.launch.report
  PYTHONPATH=src python -m repro.launch.report --baseline experiments/dryrun_baseline
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]


def load(directory: Path) -> dict:
    out = {}
    for f in directory.glob("*.json"):
        r = json.loads(f.read_text())
        out[(r.get("arch"), r.get("shape"), r.get("multi_pod"))] = r
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(ROOT / "experiments" / "dryrun"))
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--pods", type=int, default=1)
    args = ap.parse_args(argv)

    cur = load(Path(args.dir))
    base = load(Path(args.baseline)) if args.baseline else {}
    mp = args.pods == 2
    rows = sorted(k for k in cur if k[2] == mp)
    print(f"{'arch':22s} {'shape':12s} {'bound_s':>10s} {'dom':>10s} "
          f"{'frac%':>6s} {'vs-baseline':>11s}")
    n_ok = 0
    for key in rows:
        r = cur[key]
        if r.get("status") != "ok":
            print(f"{key[0]:22s} {key[1]:12s} {'FAIL':>10s}")
            continue
        n_ok += 1
        t = r["roofline"]
        frac = 100 * t["compute_s"] / t["bound_s"] if t["bound_s"] else 0
        delta = ""
        b = base.get(key)
        if b and b.get("status") == "ok":
            delta = f"x{b['roofline']['bound_s'] / t['bound_s']:.1f}"
        print(f"{key[0]:22s} {key[1]:12s} {t['bound_s']:>10.3e} "
              f"{t['dominant'].replace('_s',''):>10s} {frac:>6.1f} {delta:>11s}")
    print(f"{n_ok}/{len(rows)} cells ok (pods={args.pods})")


if __name__ == "__main__":
    main()
