"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

The same pattern as shannon/kernels: weak-type-correct, shardable stand-ins;
no device allocation ever happens for the full configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import adamw_init

Struct = jax.ShapeDtypeStruct

WHISPER_DECODER_TRAIN_LEN = 448  # whisper targets are <=448 tokens
WHISPER_DECODER_PROMPT = 8  # decoder prompt tokens at prefill


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        t = min(WHISPER_DECODER_TRAIN_LEN, cfg.max_target_len)
        return {
            "frames": Struct((b, s, cfg.d_model), jnp.float32),
            "tokens": Struct((b, t), jnp.int32),
            "targets": Struct((b, t), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        p = cfg.num_patches
        return {
            "patches": Struct((b, p, cfg.d_model), jnp.float32),
            "tokens": Struct((b, s - p), jnp.int32),
            "targets": Struct((b, s), jnp.int32),
        }
    return {
        "tokens": Struct((b, s), jnp.int32),
        "targets": Struct((b, s), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = train_batch_specs(cfg, shape)
    specs.pop("targets")
    if cfg.encoder_decoder:
        specs["tokens"] = Struct((b, WHISPER_DECODER_PROMPT), jnp.int32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(functools.partial(M.init_cache, cfg, b, s))


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "cache": cache_specs(cfg, shape),
        "token": Struct((b,), jnp.int32),
        "pos": Struct((), jnp.int32),
    }


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0)
    )


def opt_specs(params_shape):
    return jax.eval_shape(adamw_init, params_shape)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """All abstract inputs for one cell: the entry point used by dryrun.py."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)
