"""End-to-end training driver (host-scale by default, production mesh for
dry runs via launch/dryrun.py).

Example (CPU, ~2 minutes):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \\
      --d-model 128 --layers 4 --steps 50 --batch 4 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import TrainState, make_train_step
from repro.runtime.loop import LoopConfig, TrainLoop


def scaled_config(args):
    cfg = get_smoke_config(args.arch) if args.scale == "smoke" else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.layers:
        overrides["n_layers"] = args.layers
        if cfg.encoder_decoder:
            overrides["n_encoder_layers"] = args.layers
        if cfg.sliding_window:
            overrides["global_layers"] = tuple(
                g for g in cfg.global_layers if g < args.layers
            ) or (0,)
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    return dataclasses.replace(cfg, **overrides)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="bf16 gradient all-reduce compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg = scaled_config(args)
    opt_cfg = AdamWConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(10, args.steps // 20),
        grad_allreduce_dtype="bfloat16" if args.grad_compress else "float32",
    )
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    state = TrainState(params, adamw_init(params))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    pipeline = SyntheticTokenPipeline(
        DataConfig(
            seq_len=args.seq, global_batch=args.batch,
            vocab_size=cfg.vocab_size, seed=args.seed,
        )
    )
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, accum_steps=args.accum), donate_argnums=(0,)
    )

    def make_batch(np_batch):
        return {
            "tokens": jnp.asarray(np_batch["tokens"]),
            "targets": jnp.asarray(np_batch["targets"]),
        }

    ckpt = CheckpointManager(args.ckpt_dir, keep_n=3)
    loop = TrainLoop(
        step_fn, pipeline, ckpt,
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every, log_every=10),
        make_batch=make_batch,
    )
    start = 0
    if args.resume:
        start, state = loop.resume_or_init(state)
    final_step, state, history = loop.run(state, start)
    print(
        f"done at step {final_step}: loss {history[0] if history else float('nan'):.4f}"
        f" -> {history[-1] if history else float('nan'):.4f}"
    )
    return history


if __name__ == "__main__":
    main()
