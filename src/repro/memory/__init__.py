from .planner import BankPlan, PlanEntry, plan_packing, tile_efficiency  # noqa: F401
from .store import PackedParameterStore  # noqa: F401
from .tiles import TILE_ROWS, padded_bytes, tile_grid_problem  # noqa: F401
