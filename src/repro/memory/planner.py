"""Bank planner: run the paper's packers over a model's parameter tree.

Only tensors that actually waste tile padding (efficiency below a threshold)
are candidates; large tile-aligned matmul weights are left in place.  The
planner returns a BankPlan that the PackedParameterStore materializes.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import pack
from repro.memory import tiles


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    path: str
    row_offset: int
    rows: int
    cols: int
    shape: tuple[int, ...]


@dataclasses.dataclass
class BankPlan:
    itemsize: int
    banks: list[list[PlanEntry]]  # one inner list per physical bank
    unpacked: list[str]  # paths stored as plain arrays
    padded_bytes_before: int
    padded_bytes_after: int
    logical_bytes: int
    packer_result: object | None = None

    @property
    def bank_shapes(self) -> list[tuple[int, int]]:
        out = []
        sub = tiles.TILE_ROWS.get(self.itemsize, 8)
        for bank in self.banks:
            rows = sum(e.rows for e in bank)
            cols = max(e.cols for e in bank)
            out.append(
                (-(-rows // sub) * sub, -(-cols // tiles.LANES) * tiles.LANES)
            )
        return out

    @property
    def saved_bytes(self) -> int:
        return self.padded_bytes_before - self.padded_bytes_after

    def efficiency_before(self) -> float:
        return self.logical_bytes / max(1, self.padded_bytes_before)

    def efficiency_after(self) -> float:
        return self.logical_bytes / max(1, self.padded_bytes_after)


def tile_efficiency(shape: tuple[int, ...], itemsize: int) -> float:
    return tiles.logical_bytes(shape, itemsize) / max(
        1, tiles.padded_bytes(shape, itemsize)
    )


def _flatten_params(
    params, split_stacked: bool = False, n_layers: int | None = None
) -> list[tuple[str, tuple[int, ...], int]]:
    """(path, shape, itemsize) per logical buffer.

    With ``split_stacked`` every leaf under a stacked-layer collection is
    split into per-layer slices ``path#k`` — the deployment-artifact view
    (per-layer weights, as in FINN's per-layer memories and HF checkpoints).
    """
    out = []

    def path_str(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(f"layer_{p.idx}")
            else:
                parts.append(str(p))
        return "/".join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        ps = path_str(path)
        shape = tuple(leaf.shape)
        stacked = ps.startswith(("layers/", "enc_layers/")) and len(shape) >= 1
        if split_stacked and stacked and (n_layers is None or shape[0] == n_layers or True):
            for k in range(shape[0]):
                out.append((f"{ps}#{k}", shape[1:] or (1,), leaf.dtype.itemsize))
        else:
            out.append((ps, shape, leaf.dtype.itemsize))
    return out


def plan_packing(
    params,
    algorithm: str = "ga-nfd",
    max_items: int = 4,
    eff_threshold: float = 0.9,
    intra_layer: bool = False,
    max_seconds: float = 5.0,
    seed: int = 0,
    split_stacked: bool = False,
) -> dict[int, BankPlan]:
    """Plan packed banks per dtype class. Returns {itemsize: BankPlan}.

    Stacked-layer tensors (leading layer dim) are treated per-slice when the
    per-layer slice is the wasteful unit — here we keep it simple and treat
    the folded 2-D view of each leaf as one buffer (the leading layer dim
    folds into rows, so stacked tensors are already row-contiguous).
    """
    entries = _flatten_params(params, split_stacked=split_stacked)
    plans: dict[int, BankPlan] = {}
    for itemsize in sorted({e[2] for e in entries}):
        klass = [e for e in entries if e[2] == itemsize]
        candidates = [
            e for e in klass if tile_efficiency(e[1], itemsize) < eff_threshold
        ]
        skipped = [e for e in klass if e not in candidates]
        before = sum(tiles.padded_bytes(e[1], itemsize) for e in klass)
        logical = sum(tiles.logical_bytes(e[1], itemsize) for e in klass)
        if len(candidates) < 2:
            plans[itemsize] = BankPlan(
                itemsize=itemsize, banks=[], unpacked=[e[0] for e in klass],
                padded_bytes_before=before, padded_bytes_after=before,
                logical_bytes=logical,
            )
            continue
        prob, paths = tiles.tile_grid_problem(candidates, max_items=max_items)
        result = pack(
            prob, algorithm, seed=seed, max_seconds=max_seconds,
            intra_layer=intra_layer,
        )
        result.solution.validate(intra_layer=intra_layer)
        shape_by_path = {e[0]: e[1] for e in candidates}
        banks: list[list[PlanEntry]] = []
        packed_bytes = 0
        sub = tiles.TILE_ROWS.get(itemsize, 8)
        for bin_items in result.solution.bins:
            bank = []
            row = 0
            cols = 0
            for idx in bin_items:
                path = paths[idx]
                r, c = tiles.fold_2d(shape_by_path[path])
                bank.append(
                    PlanEntry(
                        path=path, row_offset=row, rows=r, cols=c,
                        shape=shape_by_path[path],
                    )
                )
                row += r
                cols = max(cols, c)
            banks.append(bank)
            packed_bytes += (
                -(-row // sub) * sub * -(-cols // tiles.LANES) * tiles.LANES * itemsize
            )
        after = packed_bytes + sum(
            tiles.padded_bytes(e[1], itemsize) for e in skipped
        )
        plans[itemsize] = BankPlan(
            itemsize=itemsize, banks=banks, unpacked=[e[0] for e in skipped],
            padded_bytes_before=before, padded_bytes_after=after,
            logical_bytes=logical, packer_result=result,
        )
    return plans
