"""PackedParameterStore: materialize a BankPlan and serve logical views.

The store holds (a) fused 2-D bank arrays for packed tensors and (b) plain
arrays for everything else.  ``view(path)`` slices a logical tensor back out
(on TPU the slice lowers to a cheap sub-tile DMA; kernels/packed_gather is
the explicit fused read path).  ``unpack()`` rebuilds the full parameter
pytree for direct use by the model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .planner import BankPlan, PlanEntry


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"layer_{p.idx}")
        else:
            parts.append(str(p))
    return "/".join(parts)


class PackedParameterStore:
    def __init__(self, params, plans: dict[int, BankPlan]):
        self.treedef = jax.tree.structure(params)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        self._leaf_order = [_path_str(p) for p, _ in flat]
        self._leaf_shapes = {_path_str(p): tuple(l.shape) for p, l in flat}
        base = {_path_str(p): leaf for p, leaf in flat}

        class _ByPath:
            """Resolves both plain paths and split-stacked 'path#k' slices."""

            def __getitem__(self, path):
                if "#" in path:
                    root, k = path.rsplit("#", 1)
                    return base[root][int(k)]
                return base[path]

            def items(self):
                return base.items()

        by_path = _ByPath()
        self.plans = plans
        self.banks: dict[tuple[int, int], jax.Array] = {}
        self.entries: dict[str, tuple[int, int, PlanEntry]] = {}
        self.plain: dict[str, jax.Array] = {}
        packed_paths = set()
        from . import tiles

        for itemsize, plan in plans.items():
            sub = tiles.TILE_ROWS.get(itemsize, 8)
            for bi, bank in enumerate(plan.banks):
                rows = sum(e.rows for e in bank)
                cols = max(e.cols for e in bank)
                prows = -(-rows // sub) * sub
                pcols = -(-cols // tiles.LANES) * tiles.LANES
                dtype = by_path[bank[0].path].dtype
                buf = jnp.zeros((prows, pcols), dtype)
                for e in bank:
                    leaf = by_path[e.path].reshape(e.rows, e.cols)
                    buf = jax.lax.dynamic_update_slice(buf, leaf, (e.row_offset, 0))
                    self.entries[e.path] = (itemsize, bi, e)
                    packed_paths.add(e.path)
                self.banks[(itemsize, bi)] = buf
        for path, leaf in by_path.items():
            if path not in packed_paths:
                self.plain[path] = leaf

    # ------------------------------------------------------------------ API
    def view(self, path: str) -> jax.Array:
        if path in self.plain:
            return self.plain[path]
        itemsize, bi, e = self.entries[path]
        bank = self.banks[(itemsize, bi)]
        block = jax.lax.dynamic_slice(bank, (e.row_offset, 0), (e.rows, e.cols))
        return block.reshape(e.shape)

    def unpack(self):
        """Rebuild the full parameter pytree (handles split-stacked leaves)."""
        leaves = []
        for p in self._leaf_order:
            if p in self.plain or p in self.entries:
                leaves.append(self.view(p).reshape(self._leaf_shapes[p]))
            else:  # split-stacked: reassemble per-layer slices
                n = self._leaf_shapes[p][0]
                slices = [self.view(f"{p}#{k}") for k in range(n)]
                leaves.append(
                    jnp.stack(slices, axis=0).reshape(self._leaf_shapes[p])
                )
        return jax.tree.unflatten(self.treedef, leaves)

    def physical_bytes(self) -> int:
        from . import tiles

        total = sum(b.size * b.dtype.itemsize for b in self.banks.values())
        total += sum(
            tiles.padded_bytes(tuple(a.shape), a.dtype.itemsize)
            for a in self.plain.values()
        )
        return total

    def stats(self) -> dict:
        out = {}
        for itemsize, plan in self.plans.items():
            out[itemsize] = dict(
                banks=len(plan.banks),
                packed_tensors=sum(len(b) for b in plan.banks),
                unpacked_tensors=len(plan.unpacked),
                padded_bytes_before=plan.padded_bytes_before,
                padded_bytes_after=plan.padded_bytes_after,
                saved_bytes=plan.saved_bytes,
                efficiency_before=plan.efficiency_before(),
                efficiency_after=plan.efficiency_after(),
            )
        return out
