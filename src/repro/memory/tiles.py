"""TPU tile-grid memory model — the hardware adaptation of the paper's BRAM.

TPU physical layout pads the last two dims of every array to (sublane, lane)
tiles: (8, 128) for 4-byte types, (16, 128) for 2-byte, (32, 128) for 1-byte.
A logical tensor folded to (rows, cols) therefore occupies

    ceil(rows / sub) * sub * ceil(cols / 128) * 128 * itemsize

bytes of physical memory — the exact analogue of the paper's Eq. 1 with
W_BRAM = 128 lanes and D_BRAM = sublane count.  Co-locating several small
tensors in one physical *bank* (rows concatenated, cols padded to the max)
amortizes the padding, which is the paper's bin-packing problem on the tile
grid.  The cardinality constraint bounds the per-bank descriptor fan-out of
the packed read path (kernels/packed_gather).
"""
from __future__ import annotations

import numpy as np

from repro.core.problem import BRAMSpec, Buffer, PackingProblem

LANES = 128
TILE_ROWS = {1: 32, 2: 16, 4: 8}  # itemsize -> sublane tile


def fold_2d(shape: tuple[int, ...]) -> tuple[int, int]:
    """Fold an N-D tensor to the (rows, cols) the TPU tiler sees."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, int(shape[0]))
    rows = 1
    for s in shape[:-1]:
        rows *= int(s)
    return (rows, int(shape[-1]))


def padded_bytes(shape: tuple[int, ...], itemsize: int) -> int:
    rows, cols = fold_2d(shape)
    sub = TILE_ROWS.get(itemsize, 8)
    prows = -(-rows // sub) * sub
    pcols = -(-cols // LANES) * LANES
    return prows * pcols * itemsize


def logical_bytes(shape: tuple[int, ...], itemsize: int) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n * itemsize


def tile_bram_spec(itemsize: int) -> BRAMSpec:
    """The tile grid as a single-mode BRAM: one 'BRAM' = one (sub x 128)
    tile; 'bits' are elements (uniform dtype within a bank)."""
    sub = TILE_ROWS.get(itemsize, 8)
    return BRAMSpec(modes=((LANES, sub),), capacity_bits=LANES * sub)


def tile_grid_problem(
    entries: list[tuple[str, tuple[int, ...], int]],
    max_items: int = 4,
    name: str = "tpu-tiles",
) -> tuple[PackingProblem, list[str]]:
    """Build a PackingProblem over the tile grid.

    entries: (param_path, shape, itemsize) — itemsize must be uniform.
    Buffer width = cols, depth = rows (transposed vs FPGA convention where
    depth is the long axis; the core model is symmetric).  The layer id is
    derived from the path's layer component when present (intra-layer
    packing keeps a layer's tensors in one contiguous DMA).
    """
    itemsizes = {e[2] for e in entries}
    if len(itemsizes) != 1:
        raise ValueError("one packing problem per dtype class")
    itemsize = itemsizes.pop()
    buffers = []
    paths = []
    for path, shape, _ in entries:
        rows, cols = fold_2d(shape)
        layer = _layer_of(path)
        buffers.append(Buffer(width=cols, depth=rows, layer=layer, name=path))
        paths.append(path)
    prob = PackingProblem(
        buffers, bram=tile_bram_spec(itemsize), max_items=max_items, name=name
    )
    return prob, paths


def _layer_of(path: str) -> int:
    if "#" in path:  # split-stacked per-layer slice: layers/attn/q/kernel#7
        try:
            return int(path.rsplit("#", 1)[1])
        except ValueError:
            pass
    for part in path.split("/"):
        if part.startswith("layer_"):
            try:
                return int(part.split("_", 1)[1])
            except ValueError:
                pass
    return 0
