"""GQA attention with RoPE, qk-norm, sliding windows, cross-attention, and a
memory-efficient blockwise (flash-style) path for long sequences.

All functions are pure JAX and GSPMD-friendly: no shard_map, so head counts
that do not divide the model axis (hymba 25q/5kv, qwen2 14q/2kv) still lower
— GSPMD pads the sharded dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense, dense_init, head_rms_norm

NEG_INF = -1e30
_U = jax.sharding.PartitionSpec.UNCONSTRAINED


def _seq_shard(x, axis: int):
    """Best-effort sequence-parallel constraint: shard dim `axis` over the
    'model' mesh axis, leaving other dims unconstrained.  A no-op outside a
    mesh context (host tests) or when the dim does not divide."""
    try:
        spec = [_U] * x.ndim
        spec[axis] = "model"
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec)
        )
    except Exception:
        return x


def _replicate_dims(x, axes):
    try:
        spec = [_U] * x.ndim
        for a in axes:
            spec[a] = None
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec)
        )
    except Exception:
        return x
_BLOCK_KV = 1024  # KV block for the flash-style path


def attn_init(cfg: ModelConfig, key, dtype) -> dict:
    kq, kk, kv, ko, s1, s2 = jax.random.split(key, 6)
    p = {
        "q": dense_init(kq, cfg.d_model, cfg.attn_dim, dtype, cfg.qkv_bias),
        "k": dense_init(kk, cfg.d_model, cfg.kv_dim, dtype, cfg.qkv_bias),
        "v": dense_init(kv, cfg.d_model, cfg.kv_dim, dtype, cfg.qkv_bias),
        "o": dense_init(ko, cfg.attn_dim, cfg.d_model, dtype, cfg.attn_out_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), dtype)
        p["k_norm"] = jnp.ones((cfg.d_head,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, params, x, kv_x, q_pos, k_pos, compute_dtype, rope: bool):
    """Returns q (B,S,Hkv,G,dh), k/v (B,T,Hkv,dh)."""
    b, s, _ = x.shape
    t = kv_x.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = hq // hkv
    q = dense(params["q"], x, compute_dtype).reshape(b, s, hq, dh)
    k = dense(params["k"], kv_x, compute_dtype).reshape(b, t, hkv, dh)
    v = dense(params["v"], kv_x, compute_dtype).reshape(b, t, hkv, dh)
    if cfg.qk_norm:
        q = head_rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = head_rms_norm(params["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)
    return q.reshape(b, s, hkv, g, dh), k, v


def _mask_bias(q_pos, k_pos, window, causal: bool):
    """(S, T) additive bias from positions. `window` may be a traced scalar;
    window <= 0 means unlimited."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones(dq.shape[:1] + dk.shape[1:], dtype=bool)
    if causal:
        ok = ok & (dk <= dq)
    win_ok = (window <= 0) | (dq - dk < window)
    ok = ok & win_ok
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, scores_dtype=jnp.float32):
    """q (B,S,N,G,D), k/v (B,T,N,D), bias (S,T) -> (B,S,N,G,D).

    ``scores_dtype`` controls the materialized score precision: fp32 for
    training numerics; the serving path passes bf16 (halves the dominant
    HBM term of long-context attention; probs renormalized in fp32 max/sum
    via the softmax below which upcasts reductions)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bsngd,btnd->bngst", q, k, preferred_element_type=scores_dtype)
    scores = (scores * scale.astype(scores_dtype)
              + bias[None, None, None, :, :].astype(scores_dtype))
    if scores_dtype == jnp.float32:
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    else:
        # serving: keep the S x T tensors in bf16; reductions in fp32
        m = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
        p = jnp.exp(scores - m.astype(scores_dtype))
        s = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (p / jnp.maximum(s, 1e-30).astype(scores_dtype)).astype(q.dtype)
    return jnp.einsum("bngst,btnd->bsngd", probs, v)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, window, causal: bool,
                    scores_dtype=jnp.float32):
    """Flash-style attention: scan over KV blocks with running max/sum.

    Memory is O(S * block) instead of O(S * T); each block step is wrapped in
    jax.checkpoint so the backward pass recomputes block scores.
    """
    b, s, n, g, d = q.shape
    t = k.shape[1]
    nblk = -(-t // _BLOCK_KV)
    pad = nblk * _BLOCK_KV - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)  # masked out
    k_blocks = k.reshape(b, nblk, _BLOCK_KV, n, d).swapaxes(0, 1)
    v_blocks = v.reshape(b, nblk, _BLOCK_KV, n, d).swapaxes(0, 1)
    p_blocks = k_pos.reshape(nblk, _BLOCK_KV)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    @jax.checkpoint
    def step(carry, blk):
        acc, row_max, row_sum = carry
        kb, vb, pb = blk
        bias = _mask_bias(q_pos, pb, window, causal)  # (S, blk)
        # the (S, blk) score/prob tensors stay in scores_dtype (bf16 on the
        # serving path — the dominant HBM term); running max/sum and the
        # accumulator remain fp32
        scores = (
            jnp.einsum("bsngd,btnd->bngst", q, kb, preferred_element_type=scores_dtype)
            * scale.astype(scores_dtype)
            + bias[None, None, None, :, :].astype(scores_dtype)
        )
        blk_max = jnp.max(scores.astype(jnp.float32), axis=-1)
        new_max = jnp.maximum(row_max, blk_max)
        correction = jnp.exp(row_max - new_max)
        probs = jnp.exp(scores - new_max[..., None].astype(scores_dtype))
        new_sum = row_sum * correction + jnp.sum(probs.astype(jnp.float32), axis=-1)
        upd = jnp.einsum("bngst,btnd->bsngd", probs.astype(q.dtype), vb)
        acc = acc * correction.transpose(0, 3, 1, 2)[..., None] + upd.astype(jnp.float32)
        return (acc, new_max, new_sum), None

    acc0 = jnp.zeros((b, s, n, g, d), jnp.float32)
    max0 = jnp.full((b, n, g, s), NEG_INF, jnp.float32)
    sum0 = jnp.zeros((b, n, g, s), jnp.float32)
    (acc, _, row_sum), _ = jax.lax.scan(step, (acc0, max0, sum0), (k_blocks, v_blocks, p_blocks))
    out = acc / jnp.maximum(row_sum, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def _sdpa_windowed_blocks(q, k, v, window: int, block_q: int = 1024,
                          scores_dtype=jnp.float32):
    """Sliding-window attention with *static* block skipping.

    For a window of W tokens, each q block [i*Bq, (i+1)*Bq) can only attend
    to k in [i*Bq - W + 1, (i+1)*Bq) — a contiguous, statically-known slice.
    We compute plain softmax attention per q block against that slice and
    never touch the other ceil(S/Bq) - 2 KV blocks, cutting both the score
    FLOPs and the materialized-score bytes by ~S/(W + Bq).

    Assumes self-attention with q_pos == k_pos == arange(S) (the prefill /
    train path); requires a static int window > 0.
    """
    b, s, n, g, d = q.shape
    bq = min(block_q, s)
    nblk = -(-s // bq)
    outs = []
    for i in range(nblk):
        q0, q1 = i * bq, min((i + 1) * bq, s)
        k0 = max(0, q0 - window + 1)
        qi = q[:, q0:q1]
        ki = k[:, k0:q1]
        vi = v[:, k0:q1]
        bias = _mask_bias(
            jnp.arange(q0, q1), jnp.arange(k0, q1), window, causal=True
        )
        outs.append(_sdpa(qi, ki, vi, bias, scores_dtype))
    return jnp.concatenate(outs, axis=1)


def attn_apply(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    q_pos: jax.Array,
    window,  # traced scalar; <=0 -> full attention
    kv_x: jax.Array | None = None,
    k_pos: jax.Array | None = None,
    causal: bool = True,
    rope: bool = True,
    return_kv: bool = False,
    scores_dtype=jnp.float32,
):
    """Full-sequence attention (training / prefill). Cross-attn when kv_x set.

    With ``return_kv`` also returns the projected (k, v) — used by prefill to
    populate the decode cache without recomputation."""
    compute_dtype = jnp.dtype(cfg.dtype)
    kv_src = x if kv_x is None else kv_x
    k_pos = q_pos if k_pos is None else k_pos
    q, k, v = _project_qkv(cfg, params, x, kv_src, q_pos, k_pos, compute_dtype, rope)
    if cfg.attn_seq_shard:
        # SP attention: q/scores sharded on sequence; K/V replicated over the
        # model axis (a small all-gather, vs score-sized partial-sum
        # all-reduces when GSPMD splits the contraction instead)
        q = _seq_shard(q, 1)
        k = _replicate_dims(k, (1, 2, 3))
        v = _replicate_dims(v, (1, 2, 3))
    windowed = (
        isinstance(window, int) and window > 0 and causal and kv_x is None
        and kv_src.shape[1] > _BLOCK_KV
    )
    if windowed:
        out = _sdpa_windowed_blocks(q, k, v, window, scores_dtype=scores_dtype)
    elif kv_src.shape[1] > _BLOCK_KV:
        out = _sdpa_blockwise(
            q, k, v, q_pos, k_pos, window, causal, scores_dtype=scores_dtype
        )
    else:
        bias = _mask_bias(q_pos, k_pos, window, causal)
        out = _sdpa(q, k, v, bias, scores_dtype)
    b, s = x.shape[:2]
    out = dense(params["o"], out.reshape(b, s, cfg.attn_dim), compute_dtype)
    if return_kv:
        return out, k, v
    return out


def attn_decode(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,  # (B, 1, D) new token hidden
    k_cache: jax.Array,  # (B, T, Hkv, dh)
    v_cache: jax.Array,
    pos: jax.Array,  # scalar int32: index of the new token
    window,  # traced scalar; <=0 full
    rope: bool = True,
    update_cache: bool = True,
    append_self: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a (possibly sliding-window) KV cache.

    Two cache disciplines:
    * ``update_cache=True`` — legacy: write the token into the cache first
      and attend over it; returns (out, new_k_cache, new_v_cache).  Flowing
      whole caches through the layer scan makes XLA rewrite the entire
      cache every step — use only for small caches.
    * ``update_cache=False, append_self=True`` — *deferred write*: attend
      over the frozen cache (positions < pos) plus the fresh (k, v) of this
      token; returns (out, k_new, v_new) and the caller performs ONE small
      stacked dynamic-update-slice for all layers after the scan (decode
      write traffic drops from O(cache) to O(tokens)).

    For windowed layers only the last `window` cache entries are sliced and
    attended (bounding the memory term); global layers read the whole cache.
    """
    compute_dtype = jnp.dtype(cfg.dtype)
    q_pos = pos[None] if pos.ndim == 0 else pos
    q, k_new, v_new = _project_qkv(
        cfg, params, x, x, q_pos[None, :], q_pos[None, :], compute_dtype, rope
    )
    if update_cache:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1
        )
    t = k_cache.shape[1]
    # hist = number of already-cached positions to attend (self excluded in
    # deferred mode — it is appended explicitly below)
    self_in_cache = update_cache
    if isinstance(window, int) and 0 < window < t:
        span = window if self_in_cache else window - 1
        start = jnp.clip(pos - span + (1 if self_in_cache else 0), 0, t - span)
        k_att = jax.lax.dynamic_slice_in_dim(k_cache, start, span, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(v_cache, start, span, axis=1)
        k_pos = start + jnp.arange(span, dtype=jnp.int32)
    else:
        k_att, v_att = k_cache, v_cache
        k_pos = jnp.arange(t, dtype=jnp.int32)
    valid = (k_pos <= pos) if self_in_cache else (k_pos < pos)
    if not isinstance(window, int):
        valid = valid & ((window <= 0) | (pos - k_pos < window))
    k_att = k_att.astype(compute_dtype)
    v_att = v_att.astype(compute_dtype)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    b = x.shape[0]
    if update_cache or not append_self:
        out = _sdpa(q, k_att, v_att, bias, scores_dtype=compute_dtype)
    else:
        # deferred write: two-part softmax merge of (frozen cache, self) —
        # concatenating along the sharded cache-seq dim would make GSPMD
        # gather the cache; the merge keeps all cross-shard reductions at
        # (B, heads) scalars.
        out = _sdpa_merge_self(q, k_att, v_att, bias, k_new, v_new)
    out = dense(params["o"], out.reshape(b, 1, cfg.attn_dim), compute_dtype)
    if update_cache:
        return out, k_cache, v_cache
    return out, k_new, v_new


def _sdpa_merge_self(q, k_cache, v_cache, bias, k_new, v_new):
    """Decode attention over [cache, self] without concatenation.

    q (B,1,N,G,D); k/v_cache (B,T,N,D); bias (1,T); k/v_new (B,1,N,D).
    Flash-style: unnormalized cache attention merged with the self term.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    sc = jnp.einsum(
        "bsngd,btnd->bngst", q, k_cache, preferred_element_type=jnp.float32
    ) * scale + bias[None, None, None, :, :]
    m_c = jnp.max(sc, axis=-1, keepdims=True)  # (B,N,G,1,1)
    p = jnp.exp(sc - m_c)
    s_c = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum(
        "bngst,btnd->bsngd", p.astype(q.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )  # (B,1,N,G,D)
    s_self = jnp.einsum(
        "bsngd,btnd->bngst", q, k_new, preferred_element_type=jnp.float32
    ) * scale  # (B,N,G,1,1)
    m = jnp.maximum(m_c, s_self)
    alpha = jnp.exp(m_c - m)  # (B,N,G,1,1)
    beta = jnp.exp(s_self - m)
    alpha_b = alpha[:, :, :, 0, 0][:, None, :, :, None]  # (B,1,N,G,1)
    beta_b = beta[:, :, :, 0, 0][:, None, :, :, None]
    num = acc * alpha_b + v_new[:, :, :, None, :].astype(jnp.float32) * beta_b
    den = (s_c * alpha + beta)[:, :, :, 0, 0][:, None, :, :, None]
    return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)
