"""Decoder/encoder blocks assembled from the mixer + MLP primitives.

Block kinds (cfg.block):
  attention — pre-norm GQA attention + (MoE or dense) MLP
  mamba2    — pre-norm SSD mixer only (no MLP, as in mamba2-1.3b)
  hymba     — parallel attention + SSM heads fused by per-branch RMSNorm
              averaging (Hymba, arXiv:2411.13676), then MLP
Whisper uses `encoder` blocks (bidirectional attention) and decoder blocks
with cross-attention (`use_cross=True`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_decode, attn_init
from .config import ModelConfig
from .layers import apply_norm, mlp_apply, mlp_init, norm_init
from .mamba2 import ssm_apply, ssm_decode, ssm_init
from .moe import moe_apply, moe_init


def _branch_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def block_init(cfg: ModelConfig, key, dtype, use_cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"norm1": norm_init(cfg, cfg.d_model, dtype)}
    if cfg.block in ("attention", "hymba"):
        p["attn"] = attn_init(cfg, ks[0], dtype)
    if cfg.block in ("mamba2", "hymba"):
        p["ssm"] = ssm_init(cfg, ks[1], dtype)
    if cfg.block == "hymba":
        p["branch_a"] = jnp.ones((cfg.d_model,), dtype)
        p["branch_s"] = jnp.ones((cfg.d_model,), dtype)
    if use_cross:
        p["norm_cross"] = norm_init(cfg, cfg.d_model, dtype)
        p["cross"] = attn_init(cfg, ks[2], dtype)
    if cfg.d_ff > 0:
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        if cfg.n_experts > 0:
            p["moe"] = moe_init(cfg, ks[3], dtype)
        else:
            p["mlp"] = mlp_init(cfg, ks[3], dtype)
    return p


def _mixer_train(cfg, p, h, positions, window, compute_dtype, rope=True):
    """The token mixer on a full sequence. Returns the residual branch."""
    hn = apply_norm(cfg, p["norm1"], h)
    if cfg.block == "attention":
        return attn_apply(cfg, p["attn"], hn, positions, window, rope=rope)
    if cfg.block == "mamba2":
        return ssm_apply(cfg, p["ssm"], hn, compute_dtype)
    if cfg.block == "hymba":
        a = attn_apply(cfg, p["attn"], hn, positions, window, rope=rope)
        s = ssm_apply(cfg, p["ssm"], hn, compute_dtype)
        return 0.5 * (
            _branch_norm(p["branch_a"], a, cfg.norm_eps)
            + _branch_norm(p["branch_s"], s, cfg.norm_eps)
        )
    raise ValueError(cfg.block)


def block_apply_train(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,
    positions: jax.Array,
    window: int,
    cross_kv: jax.Array | None = None,
    cross_pos: jax.Array | None = None,
    causal: bool = True,
    rope: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (h, aux_loss)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    aux = jnp.zeros((), jnp.float32)
    if cfg.block == "attention" and not causal:
        # encoder block: bidirectional attention
        hn = apply_norm(cfg, p["norm1"], h)
        h = h + attn_apply(
            cfg, p["attn"], hn, positions, 0, causal=False, rope=False
        )
    else:
        h = h + _mixer_train(cfg, p, h, positions, window, compute_dtype, rope=rope)
    if "cross" in p:
        hn = apply_norm(cfg, p["norm_cross"], h)
        h = h + attn_apply(
            cfg,
            p["cross"],
            hn,
            positions,
            0,
            kv_x=cross_kv,
            k_pos=cross_pos,
            causal=False,
            rope=False,
        )
    if cfg.d_ff > 0:
        hn = apply_norm(cfg, p["norm2"], h)
        if cfg.n_experts > 0:
            mlp_out, aux = moe_apply(cfg, p["moe"], hn, compute_dtype)
        else:
            mlp_out = mlp_apply(cfg, p["mlp"], hn, compute_dtype)
        h = h + mlp_out
    return h, aux


def block_prefill(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,
    positions: jax.Array,
    window: int,
    cache_len: int,
    cross_kv: jax.Array | None = None,
    cross_pos: jax.Array | None = None,
    rope: bool = True,
) -> tuple[jax.Array, dict]:
    """Full-sequence block that also emits the decode cache (padded to
    ``cache_len``). Returns (h, cache)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    cache: dict = {}
    s = h.shape[1]

    def pad_cache(kv):
        return jnp.pad(kv, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))

    hn = apply_norm(cfg, p["norm1"], h)
    if cfg.block == "attention":
        out, k, v = attn_apply(
            cfg, p["attn"], hn, positions, window, rope=rope, return_kv=True,
            scores_dtype=compute_dtype,
        )
        cache["k"], cache["v"] = pad_cache(k), pad_cache(v)
        h = h + out
    elif cfg.block == "mamba2":
        out, ssm_cache = ssm_apply(cfg, p["ssm"], hn, compute_dtype, return_state=True)
        cache["ssm"] = ssm_cache
        h = h + out
    elif cfg.block == "hymba":
        a, k, v = attn_apply(
            cfg, p["attn"], hn, positions, window, rope=rope, return_kv=True,
            scores_dtype=compute_dtype,
        )
        s_out, ssm_cache = ssm_apply(cfg, p["ssm"], hn, compute_dtype, return_state=True)
        cache["k"], cache["v"], cache["ssm"] = pad_cache(k), pad_cache(v), ssm_cache
        h = h + 0.5 * (
            _branch_norm(p["branch_a"], a, cfg.norm_eps)
            + _branch_norm(p["branch_s"], s_out, cfg.norm_eps)
        )
    if "cross" in p:
        hn = apply_norm(cfg, p["norm_cross"], h)
        out, ck, cv = attn_apply(
            cfg,
            p["cross"],
            hn,
            positions,
            0,
            kv_x=cross_kv,
            k_pos=cross_pos,
            causal=False,
            rope=False,
            return_kv=True,
            scores_dtype=compute_dtype,
        )
        cache["cross_k"], cache["cross_v"] = ck, cv
        h = h + out
    if cfg.d_ff > 0:
        hn = apply_norm(cfg, p["norm2"], h)
        if cfg.n_experts > 0:
            mlp_out, _ = moe_apply(cfg, p["moe"], hn, compute_dtype)
        else:
            mlp_out = mlp_apply(cfg, p["mlp"], hn, compute_dtype)
        h = h + mlp_out
    return h, cache


def block_decode(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,  # (B, 1, D)
    cache: dict,
    pos: jax.Array,
    window: int,
    rope: bool = True,
    defer_cache_write: bool = True,
) -> tuple[jax.Array, dict]:
    """Single-token block step against the cache.

    With ``defer_cache_write`` (production decode path) the returned dict
    carries only the new token's (k, v) — the caller batches one stacked
    cache write for all layers after the scan."""
    compute_dtype = jnp.dtype(cfg.dtype)
    new_cache = dict(cache)
    hn = apply_norm(cfg, p["norm1"], h)
    if cfg.block == "attention":
        out, k, v = attn_decode(
            cfg, p["attn"], hn, cache["k"], cache["v"], pos, window, rope=rope,
            update_cache=not defer_cache_write,
        )
        if defer_cache_write:
            new_cache = {"k_new": k, "v_new": v}
        else:
            new_cache["k"], new_cache["v"] = k, v
        h = h + out
    elif cfg.block == "mamba2":
        out, new_ssm = ssm_decode(cfg, p["ssm"], hn, cache["ssm"], compute_dtype)
        if defer_cache_write:
            new_cache = {"ssm": new_ssm}
        else:
            new_cache["ssm"] = new_ssm
        h = h + out
    elif cfg.block == "hymba":
        a, k, v = attn_decode(
            cfg, p["attn"], hn, cache["k"], cache["v"], pos, window, rope=rope,
            update_cache=not defer_cache_write,
        )
        s, new_ssm = ssm_decode(cfg, p["ssm"], hn, cache["ssm"], compute_dtype)
        if defer_cache_write:
            new_cache = {"k_new": k, "v_new": v, "ssm": new_ssm}
        else:
            new_cache["k"], new_cache["v"], new_cache["ssm"] = k, v, new_ssm
        h = h + 0.5 * (
            _branch_norm(p["branch_a"], a, cfg.norm_eps)
            + _branch_norm(p["branch_s"], s, cfg.norm_eps)
        )
    if "cross" in p:
        hn = apply_norm(cfg, p["norm_cross"], h)
        # cross K/V are precomputed at prefill; attend, never update.
        # pos=T so every encoder position is valid.
        out, _, _ = attn_decode(
            cfg,
            p["cross"],
            hn,
            cache["cross_k"],
            cache["cross_v"],
            jnp.asarray(cache["cross_k"].shape[1], jnp.int32),
            0,
            rope=False,
            update_cache=False,
            append_self=False,
        )
        h = h + out
    if cfg.d_ff > 0:
        hn = apply_norm(cfg, p["norm2"], h)
        if cfg.n_experts > 0:
            mlp_out, _ = moe_apply(cfg, p["moe"], hn, compute_dtype)
        else:
            mlp_out = mlp_apply(cfg, p["mlp"], hn, compute_dtype)
        h = h + mlp_out
    return h, new_cache
