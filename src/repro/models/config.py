"""Unified model configuration covering all assigned architectures.

One dataclass describes dense GQA transformers, MoE transformers, Mamba-2
(SSD) stacks, Hymba-style hybrid (parallel attention+SSM) blocks, Whisper
encoder-decoder, and VLM backbones with stub frontends.  Per-architecture
instances live in ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    # ---- attention (n_heads == 0 -> attention-free / pure SSM stack)
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 -> full attention in every attention layer
    global_layers: Sequence[int] = ()  # full-attention layers when SWA is on
    # sequence-parallel attention: shard the q/scores *sequence* dim over the
    # model axis instead of (too few) KV heads; K/V replicate (cheap for
    # GQA with tiny kv_dim).  Set for archs whose kv head count cannot use
    # the TP axis (qwen2: 2 kv heads vs 16-way model).
    attn_seq_shard: bool = False
    # ---- MLP
    d_ff: int = 0
    mlp_gated: bool = True  # SwiGLU-style gate+up vs plain up
    mlp_act: str = "silu"  # silu | gelu
    mlp_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # ---- MoE (replaces the dense MLP in every layer when n_experts > 0)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ---- SSM (mamba2 / hybrid)
    block: str = "attention"  # attention | mamba2 | hymba
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # ---- encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    max_target_len: int = 448
    # ---- modality frontend stub
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_patches: int = 0  # vision: patch embeddings prepended to text
    # ---- embeddings / numerics
    tie_embeddings: bool = False
    param_dtype: str = "float32"  # training; serving casts to activation dtype
    dtype: str = "bfloat16"  # activation/compute dtype
    remat: bool = True

    # ------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a lane multiple so the embedding/logits shard
        evenly over the model axis (standard production padding; the loss
        and sampling mask the padding ids)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def has_attention(self) -> bool:
        return self.block in ("attention", "hymba") and self.n_heads > 0

    def has_ssm(self) -> bool:
        return self.block in ("mamba2", "hymba")

    def is_global_layer(self, layer: int) -> bool:
        """Full attention (vs sliding window) for this layer index."""
        return self.sliding_window == 0 or layer in tuple(self.global_layers)

    # ---------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Exact parameter count (matches init_params, used for 6ND roofline)."""
        from . import model as _model  # lazy; avoids import cycle

        import jax

        params = jax.eval_shape(lambda: _model.init_params(self, jax.random.PRNGKey(0)))
        return sum(int(np_prod(x.shape)) for x in jax.tree.leaves(params))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        total = self.param_count()
        if self.n_experts == 0:
            return total
        # subtract the inactive expert fraction of expert weights
        expert_params = self.n_layers * self.n_experts * self._expert_params_per()
        active = self.n_layers * self.top_k * self._expert_params_per()
        return total - expert_params + active

    def _expert_params_per(self) -> int:
        mult = 3 if self.mlp_gated else 2
        return mult * self.d_model * self.d_ff


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
