"""Shared NN building blocks (pure JAX, explicit parameter pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / np.sqrt(max(1, shape[0] if len(shape) >= 2 else 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(
        dtype
    )


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"kernel": truncated_normal_init(key, (d_in, d_out), 1.0, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: dict, x: jax.Array, compute_dtype) -> jax.Array:
    y = jnp.matmul(x.astype(compute_dtype), params["kernel"].astype(compute_dtype))
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def norm_init(cfg: ModelConfig, d: int, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    """RMSNorm / LayerNorm in fp32 accumulation, output in x.dtype."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dt)


def head_rms_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head qk-norm (Qwen3): RMS over d_head."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {name!r}")


# ----------------------------------------------------------------- RoPE
def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d_head, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- dense MLP
def mlp_init(cfg: ModelConfig, key, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_bias),
        "down": dense_init(k2, cfg.d_ff, cfg.d_model, dtype, cfg.mlp_bias),
    }
    if cfg.mlp_gated:
        p["gate"] = dense_init(k3, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_bias)
    return p


def mlp_apply(cfg: ModelConfig, params: dict, x: jax.Array, compute_dtype) -> jax.Array:
    up = dense(params["up"], x, compute_dtype)
    if cfg.mlp_gated:
        gate = activation(cfg.mlp_act, dense(params["gate"], x, compute_dtype))
        h = gate * up
    else:
        h = activation(cfg.mlp_act, up)
    return dense(params["down"], h, compute_dtype)
