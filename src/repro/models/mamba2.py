"""Mamba-2 (SSD — state space duality, arXiv:2405.21060) block in pure JAX.

The chunked SSD algorithm: within chunks of length L the output is a masked
(C B^T)-attention against decay factors (dense matmuls, MXU-friendly); the
inter-chunk recurrence carries the (H, P, N) state with a lax.scan whose
per-step cost is tiny.  Decode is the exact single-step SSM recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, dense_init, truncated_normal_init



def ssm_init(cfg: ModelConfig, key, dtype) -> dict:
    """Parameters of one mamba2 mixer (used standalone and inside hymba).

    The reference implementation fuses [z|x|B|C|dt] into one in_proj and
    slices; under tensor parallelism the slice boundaries (4096/8192/8448/
    8512 for mamba2-1.3b) do not align with the 16-way shards and GSPMD
    emits per-layer collective-permute re-alignments.  We keep *separate*
    per-stream projections (same math, same total parameters) so every
    stream is shard-aligned — the TP-native layout.  Same for the depthwise
    conv: one (K, C) kernel per stream.
    """
    di, h, n = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(key, 8)
    return {
        "z_proj": dense_init(k1, cfg.d_model, di, dtype),
        "x_proj": dense_init(k2, cfg.d_model, di, dtype),
        "b_proj": dense_init(k3, cfg.d_model, n, dtype),
        "c_proj": dense_init(k4, cfg.d_model, n, dtype),
        "dt_proj": dense_init(k5, cfg.d_model, h, dtype),
        "conv_x": truncated_normal_init(k6, (cfg.ssm_conv_width, di), 1.0, dtype),
        "conv_x_bias": jnp.zeros((di,), dtype),
        "conv_b": truncated_normal_init(k7, (cfg.ssm_conv_width, n), 1.0, dtype),
        "conv_b_bias": jnp.zeros((n,), dtype),
        "conv_c": truncated_normal_init(k8, (cfg.ssm_conv_width, n), 1.0, dtype),
        "conv_c_bias": jnp.zeros((n,), dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)).astype(dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(k3, di, cfg.d_model, dtype),
    }


def _project_streams(cfg: ModelConfig, params: dict, x_in, compute_dtype):
    """Per-stream projections; returns (z, x, b, c, dt) pre-conv."""
    z = dense(params["z_proj"], x_in, compute_dtype)
    xs = dense(params["x_proj"], x_in, compute_dtype)
    bs = dense(params["b_proj"], x_in, compute_dtype)
    cs = dense(params["c_proj"], x_in, compute_dtype)
    dt = dense(params["dt_proj"], x_in, compute_dtype)
    return z, xs, bs, cs, dt


def _gated_norm(scale: jax.Array, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    """Mamba2's RMSNorm(y * silu(z)) output gate."""
    dt = y.dtype
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def _causal_conv(kernel: jax.Array, bias: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with a (K, C) kernel."""
    kweight = kernel.astype(x.dtype)
    kw = kweight.shape[0]
    xpad = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(
        xpad[:, i : i + x.shape[1], :] * kweight[i][None, None, :] for i in range(kw)
    )
    return jax.nn.silu(out + bias.astype(x.dtype))


def _segsum_mask(log_a: jax.Array) -> jax.Array:
    """log_a: (..., L) -> (..., L, L) lower-tri matrix exp(sum_{j<t<=i} log_a).

    The mask is applied *inside* the exp (large-negative fill) so the
    discarded upper triangle — where the raw difference is large and
    positive — can neither overflow forward nor poison gradients through
    the where (inf * 0 -> NaN)."""
    csum = jnp.cumsum(log_a, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]  # (..., i, j)
    il = jnp.tril(jnp.ones(log_a.shape[-1:] * 2, dtype=bool))
    return jnp.exp(jnp.where(il, diff, -1e30))


def ssm_apply(
    cfg: ModelConfig, params: dict, x_in: jax.Array, compute_dtype,
    return_state: bool = False,
):
    """Full-sequence SSD. x_in: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns the decode cache dict (final SSM state
    + conv tail) so prefill can hand off to single-step decoding."""
    b, s_orig, _ = x_in.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    lchunk = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % lchunk
    s = s_orig + pad
    nc = s // lchunk

    z, xs_raw, bs_raw, cs_raw, dt = _project_streams(cfg, params, x_in, compute_dtype)
    xs_conv = _causal_conv(params["conv_x"], params["conv_x_bias"], xs_raw)
    bmat = _causal_conv(params["conv_b"], params["conv_b_bias"], bs_raw)
    cmat = _causal_conv(params["conv_c"], params["conv_c_bias"], cs_raw)
    if pad:
        xs_conv = jnp.pad(xs_conv, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xs = xs_conv.reshape(b, s, h, p)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, S, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,)
    log_a = dt * a[None, None, :]  # (B, S, H) negative
    xdt = xs.astype(jnp.float32) * dt[..., None]  # dt-weighted input
    if pad:
        # padded steps must be identity on the state: decay 1, no input
        valid = (jnp.arange(s) < s_orig)[None, :]
        log_a = jnp.where(valid[..., None], log_a, 0.0)
        xdt = jnp.where(valid[..., None, None], xdt, 0.0)
        bmat = jnp.where(valid[..., None], bmat, 0.0)

    # reshape into chunks: (B, C, L, ...)
    xc = xdt.reshape(b, nc, lchunk, h, p)
    bc = bmat.reshape(b, nc, lchunk, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, lchunk, n).astype(jnp.float32)
    la = log_a.reshape(b, nc, lchunk, h)

    # --- intra-chunk (diagonal blocks): masked (C B^T) attention.
    # The L x L decay mask and C B^T products are the memory hot spot of the
    # SSD chunk algorithm (per-head L^2 tensors); they are computed in the
    # compute dtype (bf16 on TPU) with fp32 accumulation — decay cumsums
    # stay fp32 for stability.  (Perf iteration recorded in EXPERIMENTS.md.)
    lmask = _segsum_mask(la.transpose(0, 1, 3, 2))  # (B, C, H, L, L): [h,i,j]
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, C, L, L)
    y_diag = jnp.einsum(
        "bcij,bchij,bcjhp->bcihp",
        cb.astype(compute_dtype),
        lmask.astype(compute_dtype),
        xc.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )

    # --- chunk summaries: state contributed by each chunk
    csum = jnp.cumsum(la, axis=2)  # (B, C, L, H)
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)  # (B, C, L, H)
    states = jnp.einsum(
        "bcln,bclh,bclhp->bchpn",
        bc.astype(compute_dtype),
        decay_to_end.astype(compute_dtype),
        xc.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    chunk_decay = jnp.exp(csum[:, :, -1, :])  # (B, C, H) total decay per chunk

    # --- inter-chunk recurrence (tiny per-step state, sequential scan)
    def step(h_prev, inputs):
        st, dec = inputs  # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit the state *entering* the chunk

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, h_in = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_in = h_in.swapaxes(0, 1)  # (B, C, H, P, N) state entering each chunk

    # --- off-diagonal: contribution of previous chunks' state
    decay_from_start = jnp.exp(csum)  # (B, C, L, H)
    y_off = jnp.einsum(
        "bcln,bclh,bchpn->bclhp",
        cc.astype(compute_dtype),
        decay_from_start.astype(compute_dtype),
        h_in.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di)[:, :s_orig].astype(compute_dtype)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = dense(params["out_proj"], y, compute_dtype)
    if return_state:
        # decode's conv cache holds the *pre-conv* input tails per stream
        kw = cfg.ssm_conv_width - 1

        def tail(stream):
            t_ = stream[:, max(0, s_orig - kw) : s_orig, :]
            if s_orig < kw:  # left-pad zeros (conv history before t=0)
                t_ = jnp.pad(t_, ((0, 0), (kw - s_orig, 0), (0, 0)))
            return t_.astype(compute_dtype)

        cache = {
            "conv": jnp.concatenate(
                [tail(xs_raw), tail(bs_raw), tail(cs_raw)], axis=-1
            ),
            "state": h_final,
        }
        return out, cache
    return out


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def ssm_decode(
    cfg: ModelConfig, params: dict, x_in: jax.Array, cache: dict, compute_dtype
) -> tuple[jax.Array, dict]:
    """One-token SSM step. x_in: (B, 1, D)."""
    b = x_in.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs_raw, bs_raw, cs_raw, dt = _project_streams(cfg, params, x_in, compute_dtype)
    new_tok = jnp.concatenate([xs_raw, bs_raw, cs_raw], axis=-1)
    window = jnp.concatenate([cache["conv"].astype(compute_dtype), new_tok], axis=1)
    kweight = jnp.concatenate(
        [params["conv_x"], params["conv_b"], params["conv_c"]], axis=-1
    ).astype(compute_dtype)
    kbias = jnp.concatenate(
        [params["conv_x_bias"], params["conv_b_bias"], params["conv_c_bias"]],
        axis=-1,
    ).astype(compute_dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, kweight) + kbias
    conv_out = jax.nn.silu(conv_out)[:, None, :]  # (B, 1, C)
    new_conv_cache = window[:, 1:, :].astype(cache["conv"].dtype)

    xs = conv_out[..., :di].reshape(b, h, p).astype(jnp.float32)
    bvec = conv_out[..., di : di + n].reshape(b, n).astype(jnp.float32)
    cvec = conv_out[..., di + n :].reshape(b, n).astype(jnp.float32)
    dt1 = jax.nn.softplus(
        dt[:, 0, :].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a[None, :])  # (B, H)
    xdt = xs * dt1[..., None]  # (B, H, P)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, bvec
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cvec)
    y = y + xs * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(compute_dtype)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = dense(params["out_proj"], y, compute_dtype)
    return out, {"conv": new_conv_cache, "state": state}
