"""Top-level model: init, training loss, prefill, and decode.

Layers are *stacked* (leading dim = n_layers) and executed with
``jax.lax.scan`` — essential to keep XLA compile time sane for 40-layer
models on the dry-run host.  Architectures with mixed attention windows
(hymba: 3 global layers among sliding-window layers) are handled by
*segmented* scans: contiguous runs of layers sharing a static window are
scanned together, so windows stay compile-time constants (static cache
slicing in decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import block_apply_train, block_decode, block_init, block_prefill
from .config import ModelConfig
from .layers import dense_init, norm_init, apply_norm, truncated_normal_init
from .mamba2 import ssm_init_cache


# ------------------------------------------------------------------ helpers
def tree_slice(tree, start: int, end: int):
    return jax.tree.map(lambda x: x[start:end], tree)


def layer_segments(cfg: ModelConfig) -> list[tuple[int, int, int]]:
    """Contiguous (start, end, window) runs of layers with equal window."""
    if cfg.sliding_window <= 0:
        return [(0, cfg.n_layers, 0)]
    segs: list[tuple[int, int, int]] = []
    start = 0
    cur_win = 0 if cfg.is_global_layer(0) else cfg.sliding_window
    for i in range(1, cfg.n_layers):
        win = 0 if cfg.is_global_layer(i) else cfg.sliding_window
        if win != cur_win:
            segs.append((start, i, cur_win))
            start, cur_win = i, win
    segs.append((start, cfg.n_layers, cur_win))
    return segs


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10_000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# --------------------------------------------------------------------- init
def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_head, k_layers, k_enc, k_extra = jax.random.split(key, 5)
    params: dict = {
        "embed": truncated_normal_init(
            k_emb, (cfg.padded_vocab, cfg.d_model), 1.0, dtype
        ),
        "final_norm": norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.padded_vocab, dtype)
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: block_init(cfg, k, dtype, use_cross=cfg.encoder_decoder)
    )(lkeys)
    if cfg.encoder_decoder:
        ekeys = jax.random.split(k_enc, cfg.n_encoder_layers)
        params["enc_layers"] = jax.vmap(lambda k: block_init(cfg, k, dtype))(ekeys)
        params["enc_norm"] = norm_init(cfg, cfg.d_model, dtype)
        params["dec_pos"] = truncated_normal_init(
            k_extra, (cfg.max_target_len, cfg.d_model), 1.0, dtype
        )
    return params


# ------------------------------------------------------------------ forward
def _scan_blocks(cfg, stacked, h, positions, fn_builder):
    """Run segmented scans over the stacked layer params."""
    aux_total = jnp.zeros((), jnp.float32)
    for start, end, window in layer_segments(cfg):
        seg = tree_slice(stacked, start, end)
        body = fn_builder(window)
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), seg)
    return h, aux_total


def forward_hidden(
    cfg: ModelConfig,
    params: dict,
    h: jax.Array,
    positions: jax.Array,
    cross_kv=None,
    cross_pos=None,
    causal: bool = True,
    rope: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Decoder (or encoder when causal=False) stack over a full sequence."""

    def builder(window):
        def body(carry, lp):
            hh, aux = carry
            hh, a = block_apply_train(
                cfg, lp, hh, positions, window,
                cross_kv=cross_kv, cross_pos=cross_pos, causal=causal, rope=rope,
            )
            return (hh, aux + a), None

        return body

    stacked = params["layers"]
    return _scan_blocks(cfg, stacked, h, positions, builder)


def _encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    s = frames.shape[1]
    pos_emb = jnp.asarray(
        sinusoidal_positions(s, cfg.d_model), dtype=frames.dtype
    )
    h = frames + pos_emb[None]
    positions = jnp.arange(s, dtype=jnp.int32)
    aux = jnp.zeros((), jnp.float32)

    def body(carry, lp):
        hh, a = carry
        hh, _ = block_apply_train(cfg, lp, hh, positions, 0, causal=False)
        return (hh, a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, _), _ = jax.lax.scan(body_fn, (h, aux), params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], h)


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict):
    """Token (+ stub modality) embedding. Returns (h, positions)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.frontend == "vision_stub" and "patches" in batch:
        patches = batch["patches"].astype(compute_dtype)  # (B, P, D) precomputed
        h = jnp.concatenate([patches, h], axis=1)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    return h, positions


def _logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"].astype(jnp.dtype(cfg.dtype))
        return jnp.einsum("bsd,vd->bsv", h, w, preferred_element_type=jnp.float32)
    w = params["lm_head"]["kernel"].astype(jnp.dtype(cfg.dtype))
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)


def train_loss(cfg: ModelConfig, params: dict, batch: dict):
    """Cross-entropy (+ MoE aux) over the batch. Returns (loss, metrics).

    batch: tokens (B,S) int32, targets (B,S) int32 with -1 = masked;
           whisper additionally frames (B,T,D); vlm additionally patches.
    """
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"].astype(jnp.dtype(cfg.dtype)))
        tokens = batch["tokens"]
        t = tokens.shape[1]
        h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        h = h + params["dec_pos"][:t].astype(h.dtype)[None]
        positions = jnp.arange(t, dtype=jnp.int32)
        cross_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        h, aux = forward_hidden(
            cfg, params, h, positions,
            cross_kv=enc_out, cross_pos=cross_pos, rope=False,
        )
    else:
        h, positions = _embed_inputs(cfg, params, batch)
        h, aux = forward_hidden(cfg, params, h, positions)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h)  # (B, S, V) fp32

    targets = batch["targets"]
    if logits.shape[1] != targets.shape[1]:  # vlm: strip patch positions
        logits = logits[:, logits.shape[1] - targets.shape[1] :]
    mask = (targets >= 0).astype(jnp.float32)
    safe_targets = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.sum(
        logits * jax.nn.one_hot(safe_targets, logits.shape[-1], dtype=logits.dtype),
        axis=-1,
    )
    ce = (logz - gold) * mask
    loss = jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux
    metrics = {"loss": loss, "aux_loss": aux, "tokens": jnp.sum(mask)}
    return total, metrics


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """All-layer stacked decode cache (bf16 KV, fp32 SSM state)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    layers = cfg.n_layers
    cache: dict = {}
    if cfg.has_attention():
        # enc-dec: the self-attention cache is bounded by the target length;
        # cache_len sizes the cross-attention (encoder output) cache instead
        self_len = min(cache_len, cfg.max_target_len) if cfg.encoder_decoder else cache_len
        kv_shape = (layers, batch, self_len, cfg.n_kv_heads, cfg.d_head)
        cache["k"] = jnp.zeros(kv_shape, compute_dtype)
        cache["v"] = jnp.zeros(kv_shape, compute_dtype)
    if cfg.has_ssm():
        one = ssm_init_cache(cfg, batch, compute_dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (layers,) + x.shape), one
        )
    if cfg.encoder_decoder:
        cache["cross_k"] = jnp.zeros(
            (layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head), compute_dtype
        )
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache_len: int):
    """Process the prompt; returns (cache, last_token_logits)."""
    rope = True
    cross_kv = cross_pos = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"].astype(jnp.dtype(cfg.dtype)))
        tokens = batch["tokens"]
        t = tokens.shape[1]
        h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        h = h + params["dec_pos"][:t].astype(h.dtype)[None]
        positions = jnp.arange(t, dtype=jnp.int32)
        cross_kv = enc_out
        cross_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        rope = False
    else:
        h, positions = _embed_inputs(cfg, params, batch)

    caches = []
    stacked = params["layers"]
    for start, end, window in layer_segments(cfg):
        seg = tree_slice(stacked, start, end)

        def body(hh, lp, _window=window):
            hh, c = block_prefill(
                cfg, lp, hh, positions, _window, cache_len,
                cross_kv=cross_kv, cross_pos=cross_pos, rope=rope,
            )
            return hh, c

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, seg_cache = jax.lax.scan(body_fn, h, seg)
        caches.append(seg_cache)
    # concatenate per-segment stacked caches back into (L, ...) order
    cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *caches)
    h = apply_norm(cfg, params["final_norm"], h)
    logits_last = _logits(cfg, params, h[:, -1:, :])
    return cache, logits_last


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array, pos):
    """One token decode. token: (B,) int32; pos: scalar int32 position.

    Returns (new_cache, logits (B, 1, V))."""
    compute_dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"], token[:, None], axis=0).astype(compute_dtype)
    rope = True
    if cfg.encoder_decoder:
        h = h + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0
        ).astype(compute_dtype)[None]
        rope = False

    new_segs = []
    stacked = params["layers"]
    for start, end, window in layer_segments(cfg):
        seg_params = tree_slice(stacked, start, end)
        seg_cache = tree_slice(cache, start, end)

        def body(hh, xs, _window=window):
            lp, c = xs
            hh, nc = block_decode(
                cfg, lp, hh, c, pos, _window, rope=rope, defer_cache_write=True
            )
            return hh, nc

        h, new_seg_cache = jax.lax.scan(body, h, (seg_params, seg_cache))
        new_segs.append(new_seg_cache)
    ys = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_segs)
    # deferred cache write: ONE stacked update per cache tensor (the decode
    # write traffic is O(L*B*Hkv*dh), not O(cache)); donated inputs alias.
    new_cache = dict(cache)
    if "k_new" in ys:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ys["k_new"].astype(cache["k"].dtype), (0, 0, pos, 0, 0)
        )
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], ys["v_new"].astype(cache["v"].dtype), (0, 0, pos, 0, 0)
        )
    if "ssm" in ys:
        new_cache["ssm"] = ys["ssm"]
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _logits(cfg, params, h)
    return new_cache, logits
