"""Top-k MoE with grouped GShard-style one-hot dispatch/combine.

Tokens are split into groups of ``MOE_GROUP`` so the dispatch/combine
tensors stay small: per group the dispatch one-hot is (g, e*c) with
``c = g * top_k * cf / e``, i.e. total dispatch footprint scales as
``n_tokens * g * top_k * cf`` — bounded, shardable over the data axis.
The expert dimension shards over the `model` axis (expert parallelism);
XLA lowers the grouped einsums to all-to-all style collectives.

FLOP accounting matches `6 * N_active * D`: expert GEMMs run on
``top_k * cf`` slots per token, never on all experts.  (The one-hot
dispatch einsum itself costs extra FLOPs — the known GShard overhead; the
sort-based dropless alternative is a recorded §Perf candidate.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import activation, truncated_normal_init

MOE_GROUP = 512  # tokens per dispatch group


def moe_init(cfg: ModelConfig, key, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": truncated_normal_init(kr, (d, e), 1.0, dtype),
        "up": truncated_normal_init(ku, (e, d, f), 1.0, dtype),
        "down": truncated_normal_init(kd, (e, f, d), 1.0, dtype),
    }
    if cfg.mlp_gated:
        p["gate"] = truncated_normal_init(kg, (e, d, f), 1.0, dtype)
    return p


def moe_apply(
    cfg: ModelConfig, params: dict, x: jax.Array, compute_dtype
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Over-capacity tokens are dropped."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    g = min(MOE_GROUP, n)
    pad = (-n) % g
    xt = x.reshape(n, d).astype(compute_dtype)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = (n + pad) // g
    xg = xt.reshape(ng, g, d)  # (G, g, d)

    logits = jnp.einsum(
        "Gnd,de->Gne", xg, params["router"].astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, e) fp32
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    cap = max(1, int(g * k * cfg.capacity_factor / e))
    # position of each (token, slot) within its expert queue, FIFO over (g*k)
    assign = jax.nn.one_hot(gate_idx.reshape(ng, g * k), e, dtype=jnp.float32)
    pos = jnp.cumsum(assign, axis=1) * assign - assign  # (G, g*k, e)
    pos = jnp.sum(pos, axis=-1).reshape(ng, g, k)  # position per slot
    keep = pos < cap  # (G, g, k)

    # flat slot id = expert * cap + pos; invalid slots point past the table
    slot = jnp.where(keep, gate_idx * cap + pos.astype(jnp.int32), e * cap)
    slot_oh = jax.nn.one_hot(slot, e * cap, dtype=compute_dtype)  # (G, g, k, e*c)
    dispatch = jnp.sum(slot_oh, axis=2)  # (G, g, e*c)
    combine = jnp.sum(slot_oh * gate_vals[..., None].astype(compute_dtype), axis=2)

    expert_in = jnp.einsum(
        "Gns,Gnd->Gsd", dispatch, xg, preferred_element_type=compute_dtype
    ).reshape(ng, e, cap, d)
    up = jnp.einsum(
        "Gecd,edf->Gecf", expert_in, params["up"].astype(compute_dtype),
        preferred_element_type=compute_dtype,
    )
    if cfg.mlp_gated:
        gate = jnp.einsum(
            "Gecd,edf->Gecf", expert_in, params["gate"].astype(compute_dtype),
            preferred_element_type=compute_dtype,
        )
        h = activation(cfg.mlp_act, gate) * up
    else:
        h = activation(cfg.mlp_act, up)
    expert_out = jnp.einsum(
        "Gecf,efd->Gecd", h, params["down"].astype(compute_dtype),
        preferred_element_type=compute_dtype,
    ).reshape(ng, e * cap, d)
    out = jnp.einsum(
        "Gns,Gsd->Gnd", combine, expert_out, preferred_element_type=compute_dtype
    )
    out = out.reshape(n + pad, d)[:n].reshape(b, s, d)

    # load-balance auxiliary loss (Switch/GShard)
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx.reshape(-1, k)[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux = jnp.sum(me * ce) * e * cfg.router_aux_weight
    return out, aux.astype(jnp.float32)
