"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Written against pytrees directly (no optax in the offline env).  Optimizer
state mirrors the parameter tree (m, v in fp32) plus a scalar step — the
checkpoint layer serializes it like any other tree.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # cast gradients to bf16 before the cross-replica mean (DP all-reduce
    # compression; fp32 master weights keep the update exact-ish)
    grad_allreduce_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(1.0, cfg.warmup_steps)
    progress = (step_f - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * jnp.where(step_f < cfg.warmup_steps, warm, decay)


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "learning_rate": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
