"""Fault-tolerant training loop.

Production behaviors on top of the bare train_step:
* checkpoint/restart (resume from latest; data-iterator state rides along);
* NaN/Inf loss detection with rollback-and-skip (reload last good
  checkpoint, fast-forward the data pipeline past the poison window);
* SIGTERM/SIGINT emergency checkpoint (preemption-safe);
* step-time EWMA heartbeat — the per-host hook where a multi-host deploy
  reports to the straggler detector (slowest-worker logging here);
* periodic + final checkpointing, async writes.
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.steps import TrainState

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    rollback_on_nan: bool = True
    max_nan_rollbacks: int = 3
    straggler_factor: float = 2.0  # heartbeat: warn when step > factor * EWMA


class TrainLoop:
    def __init__(
        self,
        train_step,  # jitted (state, batch) -> (state, metrics)
        pipeline,  # SyntheticTokenPipeline-like (next_batch/state/restore)
        ckpt: CheckpointManager,
        cfg: LoopConfig,
        make_batch=lambda np_batch: np_batch,
    ):
        self.train_step = train_step
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.cfg = cfg
        self.make_batch = make_batch
        self._interrupted = False
        self._ewma = None

    # ---------------------------------------------------------------- run
    def run(self, state: TrainState, start_step: int = 0):
        cfg = self.cfg
        step = start_step
        nan_rollbacks = 0
        old_handlers = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            old_handlers[sig] = signal.signal(sig, self._on_signal)
        history = []
        try:
            while step < cfg.total_steps:
                if self._interrupted:
                    log.warning("interrupt: emergency checkpoint at step %d", step)
                    self.ckpt.save(step, state, extra={"data": self.pipeline.state()})
                    self.ckpt.wait()
                    break
                t0 = time.perf_counter()
                np_batch = self.pipeline.next_batch()
                batch = self.make_batch(np_batch)
                state, metrics = self.train_step(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self._heartbeat(step, dt)
                if not np.isfinite(loss):
                    if cfg.rollback_on_nan and nan_rollbacks < cfg.max_nan_rollbacks:
                        nan_rollbacks += 1
                        log.error(
                            "non-finite loss at step %d; rollback #%d", step,
                            nan_rollbacks,
                        )
                        step, state = self._rollback(state)
                        continue
                    raise FloatingPointError(f"non-finite loss at step {step}")
                history.append(loss)
                step += 1
                if step % cfg.log_every == 0:
                    log.info("step %d loss %.4f (%.2fs/step)", step, loss, dt)
                if step % cfg.ckpt_every == 0:
                    self.ckpt.save(step, state, extra={"data": self.pipeline.state()})
            self.ckpt.save(step, state, extra={"data": self.pipeline.state()})
            self.ckpt.wait()
        finally:
            for sig, h in old_handlers.items():
                signal.signal(sig, h)
        return step, state, history

    # ------------------------------------------------------------- helpers
    def _on_signal(self, signum, frame):
        self._interrupted = True

    def _heartbeat(self, step: int, dt: float) -> None:
        if self._ewma is None:
            self._ewma = dt
        if dt > self.cfg.straggler_factor * self._ewma and step > 3:
            # multi-host: this is where the controller would be notified /
            # the slow host replaced; single-host: log it
            log.warning(
                "straggler heartbeat: step %d took %.2fs (EWMA %.2fs)",
                step, dt, self._ewma,
            )
        self._ewma = 0.9 * self._ewma + 0.1 * dt

    def _rollback(self, state: TrainState):
        step = self.ckpt.latest_step()
        if step is None:
            raise FloatingPointError("non-finite loss before first checkpoint")
        like = jax.tree.map(np.asarray, state)
        step, restored, extra = self.ckpt.restore(like)
        self.pipeline.restore(extra["data"])
        # skip past the poisoned window deterministically
        self.pipeline.next_batch()
        return step, jax.tree.map(jax.numpy.asarray, restored)

    def resume_or_init(self, init_state: TrainState):
        step = self.ckpt.latest_step()
        if step is None:
            return 0, init_state
        like = jax.tree.map(np.asarray, init_state)
        step, restored, extra = self.ckpt.restore(like)
        self.pipeline.restore(extra["data"])
        log.info("resumed from checkpoint step %d", step)
        return step, jax.tree.map(jax.numpy.asarray, restored)
