"""Step builders: the jit-able train / prefill / decode functions.

``make_train_step`` supports microbatched gradient accumulation (scan over
micro-slices) and optional bf16 gradient all-reduce compression (cast before
the cross-replica mean — the DP all-reduce then moves half the bytes; params
and optimizer state stay fp32).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: Any


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return M.train_loss(cfg, params, batch)

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict):
        params = state.params
        if accum_steps > 1:
            # microbatch over the leading batch dim: (B,) -> (A, B/A)
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                loss, _, grads = compute_grads(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = compute_grads(params, batch)

        if opt_cfg.grad_allreduce_dtype == "bfloat16":
            # gradient compression: halve DP all-reduce bytes
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, state.opt
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["total_loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)

    return decode_step
