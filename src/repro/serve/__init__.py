"""Packing as a service: async micro-batching front-end over the sweep core.

See docs/DESIGN.md section 15.  Quickstart::

    from repro.serve import PackingService

    async with PackingService("sa-s", store_dir="./pack_store",
                              backend="python", max_iterations=200,
                              patience=10**9, max_seconds=1e9) as svc:
        res = await svc.pack(problem, seed=3)      # == pack(problem, ...)
        print(svc.stats()["latency_solved"])
"""
from .batching import MicroBatcher, Request  # noqa: F401
from .service import PackingService  # noqa: F401
from .stats import Histogram, LatencyStats  # noqa: F401
from .store import ResultStore  # noqa: F401
from .traffic import (  # noqa: F401
    Arrival,
    make_problems,
    make_workload,
    result_signature,
    run_traffic,
    verify_parity,
)
