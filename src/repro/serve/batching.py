"""Micro-batching policy: pure, clock-injected, event-loop-agnostic.

The :class:`MicroBatcher` holds pending requests bucketed by their
``batch_group_key`` (problems sharing a cost-model signature can ride one
batched fleet — see ``repro.core.problem.batch_group_key``) and decides
*when* each bucket flushes:

* **size**: a bucket reaching ``max_batch`` flushes immediately;
* **age**: a bucket whose oldest request has waited ``max_wait_ms``
  flushes with whatever it has — bounded queueing delay;
* **deadline**: a request whose ``deadline_ms`` budget is too tight to
  ride out the batching window flushes its bucket *now*, alone if nobody
  compatible is waiting — the single-candidate fallback.  Trading batch
  occupancy for tail latency is exactly the knob the deadline requests;
  the result is still bit-identical (batch shape never changes answers).

All time handling goes through explicit ``now`` arguments so tests drive
the policy with a fake clock; the service supplies ``time.monotonic``.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass, field

from ..core.problem import PackingProblem


@dataclass
class Request:
    """One in-flight ``pack`` request inside the service."""

    prob: PackingProblem
    seed: int
    key: tuple  # full task identity (repro.core.dse.task_key)
    group: tuple  # batch_group_key(prob) — batching compatibility class
    future: asyncio.Future
    arrival: float  # service clock at admission
    flush_at: float  # batching window closes (age or deadline pressure)
    deadline_at: float | None = None  # absolute deadline, service clock
    deadline_rushed: bool = field(default=False)  # flushed early for deadline


class MicroBatcher:
    def __init__(self, max_batch: int = 8, max_wait_ms: float = 5.0):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self._buckets: dict[tuple, list[Request]] = {}

    def pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def admit(self, req: Request, now: float) -> None:
        """Place ``req`` in its bucket and stamp its flush window.

        ``req.deadline_at`` (stamped by the service at *arrival*, so queue
        time counts against the budget) tighter than the batching window
        collapses the window to "now" — the next ``pop_ready`` emits the
        bucket even if it only holds this one request (single-candidate
        fallback).
        """
        req.flush_at = now + self.max_wait_s
        if req.deadline_at is not None and req.deadline_at < req.flush_at:
            req.flush_at = now
            req.deadline_rushed = True
        self._buckets.setdefault(req.group, []).append(req)

    def next_flush_at(self) -> float | None:
        """Earliest moment any bucket's window closes (None: nothing pending).

        The service sleeps at most until this point before re-polling
        ``pop_ready`` — full buckets never wait on it because ``admit`` is
        always followed by a ``pop_ready`` pass.
        """
        times = [r.flush_at for b in self._buckets.values() for r in b]
        return min(times) if times else None

    def pop_ready(self, now: float) -> list[list[Request]]:
        """Remove and return every batch due at ``now``.

        Full buckets emit ``max_batch``-sized slices oldest-first; a bucket
        whose window has closed emits whatever it holds.  Requests never
        linger past their ``flush_at``.
        """
        out: list[list[Request]] = []
        for group in list(self._buckets):
            bucket = self._buckets[group]
            while len(bucket) >= self.max_batch:
                out.append(bucket[: self.max_batch])
                del bucket[: self.max_batch]
            if bucket and min(r.flush_at for r in bucket) <= now:
                out.append(bucket)
                bucket = []
            if bucket:
                self._buckets[group] = bucket
            else:
                del self._buckets[group]
        return out

    def drain(self) -> list[list[Request]]:
        """Flush everything regardless of windows (shutdown path)."""
        out = []
        for bucket in self._buckets.values():
            for i in range(0, len(bucket), self.max_batch):
                out.append(bucket[i : i + self.max_batch])
        self._buckets.clear()
        return out
