"""Packing-as-a-service: async front-end over the batched sweep core.

:class:`PackingService` accepts ``pack`` requests from many concurrent
asyncio clients and answers each one bit-identically to a standalone
``repro.core.pack(problem, seed=s)`` call with the service's solver
settings.  The pipeline, in lookup order per request:

1. **coalesce** — an identical request (same task key: fingerprint +
   algorithm + seed + settings) already in flight shares its future; N
   concurrent duplicates cost exactly one solve;
2. **memory cache** — previously answered this process, served instantly;
3. **result store** — previously answered *any* process over this store
   dir (:class:`repro.serve.store.ResultStore`), digest-verified read;
4. **solve** — enqueued (bounded queue → backpressure), micro-batched by
   ``batch_group_key`` under the :class:`repro.serve.batching.MicroBatcher`
   policy, and executed as one ``repro.core.dse.solve_batch`` fleet on a
   single-dispatch worker lane (one thread, one batch at a time — the
   evaluation engines own the parallelism).

Bit-parity argument: per-problem RNG streams make every fleet candidate
bit-identical to its standalone run (the PR-4 contract, pinned by
tests/test_dse.py), so batch composition — who you share a micro-batch
with, cache hits, coalescing — is an execution-shape knob, never a
semantics change.  ``tests/test_serve_property.py`` pins this end to end.

Solver settings (algorithm, backend, budgets, hyperparameters) are fixed
per service instance; requests carry only ``(problem, seed, deadline_ms)``.
A ``deadline_ms`` too tight for the batching window skips it (single-
candidate fallback; see batching.py).  ``stats()`` is the observability
surface; ``drain()``/``stop()`` finish accepted work before shutdown.
"""
from __future__ import annotations

import asyncio
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

from ..core import dse
from ..core.problem import PackingProblem, PackingResult, batch_group_key
from .batching import MicroBatcher, Request
from .stats import Histogram, LatencyStats
from .store import ResultStore

_CLOSE = object()  # queue sentinel: no more requests will arrive


class PackingService:
    def __init__(
        self,
        algorithm: str = "sa-s",
        store_dir: str | Path | None = None,
        *,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        max_queue: int = 64,
        max_seconds: float = 30.0,
        intra_layer: bool = False,
        backend: str = "auto",
        clock=time.monotonic,
        **hyper,
    ):
        self.algorithm = algorithm.lower()
        self.max_seconds = float(max_seconds)
        self.intra_layer = bool(intra_layer)
        self.backend = backend
        self.hyper = dse.normalize_hyper(self.algorithm, hyper)
        self.store = (
            ResultStore(store_dir, memory_cache=False)
            if store_dir is not None
            else None
        )
        self.max_queue = int(max_queue)
        self._clock = clock
        self._batcher = MicroBatcher(max_batch=max_batch, max_wait_ms=max_wait_ms)
        self._queue: asyncio.Queue | None = None
        self._batch_task: asyncio.Task | None = None
        self._solve_tasks: set[asyncio.Task] = set()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="pack-serve"
        )
        self._inflight: dict[tuple, asyncio.Future] = {}
        self._results: dict[tuple, PackingResult] = {}
        self._closed = False
        # ----------------------------------------------- observability
        self.n_requests = 0
        self.n_coalesced = 0
        self.n_mem_hits = 0
        self.n_store_hits = 0
        self.n_solved = 0
        self.n_batches = 0
        self.n_deadline_fallbacks = 0
        self.occupancy = Histogram()
        self.lat_cached = LatencyStats()
        self.lat_solved = LatencyStats()

    # ------------------------------------------------------------ lifecycle
    async def __aenter__(self) -> "PackingService":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def _ensure_started(self) -> None:
        if self._closed:
            raise RuntimeError("PackingService is stopped")
        if self._queue is None:
            self._queue = asyncio.Queue(maxsize=self.max_queue)
            self._batch_task = asyncio.create_task(self._batch_loop())

    async def drain(self) -> None:
        """Wait until every accepted request has been answered."""
        while self._queue is not None and (
            not self._queue.empty()
            or self._batcher.pending()
            or self._solve_tasks
            or self._inflight
        ):
            tasks = list(self._solve_tasks)
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            else:
                # waiting on a batching window, not on solver work
                await asyncio.sleep(self._batcher.max_wait_s / 4 or 0.001)

    async def stop(self) -> None:
        """Drain accepted work, stop the loops, release the worker lane."""
        if self._closed:
            return
        self._closed = True
        if self._queue is not None:
            await self._queue.put(_CLOSE)
            await self._batch_task
            if self._solve_tasks:
                await asyncio.gather(*list(self._solve_tasks),
                                     return_exceptions=True)
        self._pool.shutdown(wait=True)

    # -------------------------------------------------------------- request
    def task_key(self, prob: PackingProblem, seed: int) -> tuple:
        return dse.task_key(
            prob,
            self.algorithm,
            seed,
            intra_layer=self.intra_layer,
            backend=self.backend,
            max_seconds=self.max_seconds,
            hyper=self.hyper,
        )

    async def pack(
        self,
        prob: PackingProblem,
        seed: int = 0,
        deadline_ms: float | None = None,
    ) -> PackingResult:
        """Answer one packing request (bit-identical to standalone pack).

        Awaiting may block on the bounded request queue when the service is
        saturated — that *is* the backpressure contract: admission slows to
        the worker lane's pace instead of queueing unboundedly.
        """
        self._ensure_started()
        t0 = self._clock()
        self.n_requests += 1
        key = self.task_key(prob, seed)

        fut = self._inflight.get(key)
        if fut is not None:
            self.n_coalesced += 1
            res = await asyncio.shield(fut)
            self.lat_solved.record(self._clock() - t0)
            return res

        res = self._results.get(key)
        if res is not None:
            self.n_mem_hits += 1
            self.lat_cached.record(self._clock() - t0)
            return res

        if self.store is not None:
            res = self.store.get(key, prob)
            if res is not None:
                self.n_store_hits += 1
                self._results[key] = res
                self.lat_cached.record(self._clock() - t0)
                return res

        fut = asyncio.get_running_loop().create_future()
        self._inflight[key] = fut
        req = Request(
            prob=prob,
            seed=seed,
            key=key,
            group=batch_group_key(prob),
            future=fut,
            arrival=t0,
            flush_at=t0,
            deadline_at=(
                t0 + float(deadline_ms) / 1e3 if deadline_ms is not None
                else None
            ),
        )
        try:
            await self._queue.put(req)  # bounded: blocks when saturated
        except BaseException:
            # never admitted: drop the in-flight slot so later duplicates
            # don't coalesce onto a future nobody will resolve
            if self._inflight.get(key) is fut:
                del self._inflight[key]
            raise
        res = await asyncio.shield(fut)
        self.lat_solved.record(self._clock() - t0)
        return res

    # ------------------------------------------------------------- batching
    async def _batch_loop(self) -> None:
        closing = False
        while not closing:
            flush_at = self._batcher.next_flush_at()
            timeout = (
                None if flush_at is None
                else max(0.0, flush_at - self._clock())
            )
            item: object | None
            try:
                item = await asyncio.wait_for(self._queue.get(), timeout)
            except asyncio.TimeoutError:
                item = None
            # drain whatever else arrived in the same loop tick — cheaper
            # batches and no spurious window churn
            while item is not None:
                if item is _CLOSE:
                    closing = True
                else:
                    self._batcher.admit(item, self._clock())
                try:
                    item = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    item = None
            batches = (
                self._batcher.drain() if closing
                else self._batcher.pop_ready(self._clock())
            )
            for batch in batches:
                task = asyncio.create_task(self._run_batch(batch))
                self._solve_tasks.add(task)
                task.add_done_callback(self._solve_tasks.discard)

    async def _run_batch(self, batch: list[Request]) -> None:
        self.n_batches += 1
        self.occupancy.record(len(batch))
        if any(r.deadline_rushed for r in batch):
            self.n_deadline_fallbacks += 1
        probs = [r.prob for r in batch]
        seeds = [r.seed for r in batch]
        loop = asyncio.get_running_loop()
        try:
            results = await loop.run_in_executor(
                self._pool, self._solve, probs, seeds
            )
        except Exception as e:
            for r in batch:
                self._inflight.pop(r.key, None)
                if not r.future.done():
                    r.future.set_exception(e)
            return
        for r, res in zip(batch, results):
            self._results[r.key] = res
            if self.store is not None:
                self.store.put(r.key, res)
            self.n_solved += 1
            self._inflight.pop(r.key, None)
            if not r.future.done():
                r.future.set_result(res)

    def _solve(self, probs, seeds) -> list[PackingResult]:
        # worker-lane thread; ThreadPoolExecutor(max_workers=1) serializes
        # batches so the engines never contend for the evaluation backend
        return dse.solve_batch(
            probs,
            algorithm=self.algorithm,
            seeds=seeds,
            max_seconds=self.max_seconds,
            intra_layer=self.intra_layer,
            backend=self.backend,
            **self.hyper,
        )

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        hits = self.n_coalesced + self.n_mem_hits + self.n_store_hits
        return {
            "requests": self.n_requests,
            "coalesced": self.n_coalesced,
            "cache_hits_mem": self.n_mem_hits,
            "cache_hits_store": self.n_store_hits,
            "hit_rate": hits / self.n_requests if self.n_requests else 0.0,
            "solved": self.n_solved,
            "batches": self.n_batches,
            "deadline_fallbacks": self.n_deadline_fallbacks,
            "queue_depth": self._queue.qsize() if self._queue else 0,
            "pending": self._batcher.pending(),
            "inflight": len(self._inflight),
            "batch_occupancy": self.occupancy.summary(),
            "latency_cached": self.lat_cached.summary(),
            "latency_solved": self.lat_solved.summary(),
            "store": self.store.stats() if self.store is not None else None,
        }
