"""Observability counters for the packing service.

Plain-python accumulators — no locks needed because everything that
mutates them runs on the service's event loop thread (the worker lane
hands results back via ``loop.call_soon_threadsafe``-free futures awaited
on the loop).
"""
from __future__ import annotations

import bisect
import math


class LatencyStats:
    """Streaming latency recorder with exact small-N percentiles.

    Keeps a sorted list of samples (bounded by ``cap``; beyond it the
    reservoir keeps every k-th sample, which is more than precise enough
    for a benchmark harness) and answers p50/p99 in O(1).
    """

    def __init__(self, cap: int = 100_000):
        self.cap = cap
        self._sorted: list[float] = []
        self._stride = 1
        self._skip = 0
        self.count = 0
        self.total = 0.0

    def record(self, seconds: float) -> None:
        self.count += 1
        self.total += seconds
        self._skip += 1
        if self._skip < self._stride:
            return
        self._skip = 0
        if len(self._sorted) >= self.cap:
            # halve the resolution instead of dropping the tail: keep every
            # other retained sample so old and new eras stay represented
            self._sorted = self._sorted[::2]
            self._stride *= 2
        bisect.insort(self._sorted, seconds)

    def percentile(self, q: float) -> float:
        if not self._sorted:
            return 0.0
        # nearest-rank: smallest sample with at least ceil(q*n) samples <= it.
        # (round-half-up interpolation overshoots at small N: p50 of two
        # samples must be the lower one, not the upper)
        n = len(self._sorted)
        idx = max(0, min(n - 1, math.ceil(q * n) - 1))
        return self._sorted[idx]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean,
            "p50_s": self.percentile(0.50),
            "p99_s": self.percentile(0.99),
        }


class Histogram:
    """Integer-valued histogram (batch occupancy, queue depth samples)."""

    def __init__(self):
        self.counts: dict[int, int] = {}

    def record(self, value: int) -> None:
        self.counts[int(value)] = self.counts.get(int(value), 0) + 1

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    @property
    def mean(self) -> float:
        n = self.total
        return (
            sum(k * v for k, v in self.counts.items()) / n if n else 0.0
        )

    def summary(self) -> dict:
        return {
            "counts": {str(k): v for k, v in sorted(self.counts.items())},
            "mean": self.mean,
        }
