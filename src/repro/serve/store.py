"""Persistent fingerprint-keyed result store for the packing service.

One entry per task key (:func:`repro.core.dse.task_key` — problem
fingerprint + algorithm + seed + settings), laid out with the repo-wide
durable-artifact convention of ``repro.checkpoint`` (shared helpers
``write_atomic_dir``/``read_atomic_dir``):

    <dir>/entry_<digest>/
        arrays.npz     — the packing itself: flattened bins + kind lane
        manifest.json  — format, task digest, sha256 of arrays.npz, and the
                         JSON remainder of the PackingResult (cost,
                         efficiency, trace, iterations, params, ...)

Guarantees:

* **atomic**: entries are written to a unique scratch dir and published
  with one ``os.rename`` — a crash mid-write never leaves a half-written
  entry, and a *concurrent second writer* that loses the publish race
  discards its scratch copy instead of touching the winner (safe because
  entries are immutable: equal task keys mean bit-identical results, the
  sweep-parity contract of docs/DESIGN.md section 10);
* **digest-verified reads**: ``get`` sha256-checks ``arrays.npz`` against
  the manifest and validates the task digest; a torn, corrupted, or
  half-deleted entry is *skipped with a logged warning and never served* —
  the caller simply recomputes (and the recompute's ``put`` replaces the
  damaged entry);
* **warm restarts**: a service restarted over the same store dir serves
  every previously-completed task from disk, bit-identically.

Results round-trip through the ``repro.core.resume`` result codec, the
same serializer the crash-safe sweep checkpoints use, so "stored result"
and "checkpointed result" can never drift apart.
"""
from __future__ import annotations

import logging
import shutil
from pathlib import Path

import numpy as np

from ..checkpoint import read_atomic_dir, write_atomic_dir
from ..core.problem import PackingProblem, PackingResult, Solution
from ..core.resume import result_from_state, result_state, task_digest

logger = logging.getLogger(__name__)

FORMAT = 1

_PREFIX = "entry_"


def _solution_arrays(sol: Solution) -> dict[str, np.ndarray]:
    """Flatten a ragged packing into dense int64 arrays for ``arrays.npz``."""
    return {
        "bins_flat": np.asarray(
            [i for b in sol.bins for i in b], dtype=np.int64
        ),
        "bin_sizes": np.asarray([len(b) for b in sol.bins], dtype=np.int64),
        "kinds": np.asarray(sol.kinds, dtype=np.int64),
    }


def _solution_state(flat: dict[str, np.ndarray]) -> dict:
    """Rebuild the ``Solution.state_dict`` payload from the dense arrays."""
    sizes = flat["bin_sizes"]
    if len(flat["kinds"]) != len(sizes):
        raise IOError("kind lane misaligned with bins")
    if int(sizes.sum()) != len(flat["bins_flat"]):
        raise IOError("bin sizes do not cover the flattened items")
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    bins = [
        [int(i) for i in flat["bins_flat"][offsets[b]:offsets[b + 1]]]
        for b in range(len(sizes))
    ]
    return {"bins": bins, "kinds": [int(k) for k in flat["kinds"]]}


class ResultStore:
    """Persistent, digest-verified map ``task key -> PackingResult``.

    ``memory_cache=True`` (the default) keeps deserialized results in an
    in-process dict, so repeat hits after the first disk read are
    allocation-free — the warm-traffic fast path of the service.
    """

    def __init__(self, directory: str | Path, memory_cache: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._mem: dict[str, PackingResult] | None = {} if memory_cache else None
        # observability counters (served by PackingService.stats())
        self.hits = 0
        self.misses = 0
        self.corrupt_skipped = 0
        self.lost_races = 0

    # ------------------------------------------------------------- layout
    def path_for(self, key: tuple) -> Path:
        return self.dir / f"{_PREFIX}{task_digest(key)}"

    def digests(self) -> list[str]:
        """Digests of the complete-looking entries on disk (unverified)."""
        out = []
        for p in self.dir.glob(f"{_PREFIX}*"):
            if ".tmp" in p.name or not (p / "manifest.json").is_file():
                continue
            out.append(p.name[len(_PREFIX):])
        return sorted(out)

    def __len__(self) -> int:
        return len(self.digests())

    def __contains__(self, key: tuple) -> bool:
        d = task_digest(key)
        if self._mem is not None and d in self._mem:
            return True
        return (self.path_for(key) / "manifest.json").is_file()

    # ---------------------------------------------------------------- get
    def get(self, key: tuple, prob: PackingProblem) -> PackingResult | None:
        """The stored result for ``key``, or None (miss / damaged entry).

        A damaged entry — torn npz, scribbled manifest, missing file, task
        digest mismatch, sha256 mismatch — is **never served**: it logs a
        warning, counts in ``corrupt_skipped``, and reads as a miss so the
        caller recomputes (whose ``put`` then replaces the damage).
        """
        digest = task_digest(key)
        if self._mem is not None:
            res = self._mem.get(digest)
            if res is not None:
                self.hits += 1
                return res
        path = self.dir / f"{_PREFIX}{digest}"
        if not path.exists():
            self.misses += 1
            return None
        try:
            flat, manifest = read_atomic_dir(path)
            if manifest.get("format") != FORMAT:
                raise IOError(f"entry format {manifest.get('format')!r}")
            if manifest.get("digest") != digest:
                raise IOError("entry digest does not match its key")
            state = dict(manifest["result"])
            state["solution"] = _solution_state(flat)
            res = result_from_state(prob, state)
        except Exception as e:
            self.corrupt_skipped += 1
            self.misses += 1
            logger.warning(
                "skipping corrupt result-store entry %s: %s", path, e
            )
            return None
        self.hits += 1
        if self._mem is not None:
            self._mem[digest] = res
        return res

    # ---------------------------------------------------------------- put
    def put(self, key: tuple, res: PackingResult) -> bool:
        """Persist ``res`` under ``key``; returns False on a lost race.

        An existing *intact* entry is left untouched (immutable-content
        contract); an existing *damaged* entry is swapped out for the fresh
        result.  Either way the publish is a single atomic rename.
        """
        digest = task_digest(key)
        if self._mem is not None:
            self._mem[digest] = res
        state = result_state(res)
        solution = state.pop("solution")
        path = self.dir / f"{_PREFIX}{digest}"
        manifest = {"format": FORMAT, "digest": digest, "result": state}
        arrays = _solution_arrays(res.solution)
        del solution  # bins/kinds travel in arrays.npz, not the manifest
        if write_atomic_dir(path, arrays, manifest, replace=False):
            return True
        # final exists: keep it if intact, replace it if damaged
        try:
            _, existing = read_atomic_dir(path)
            if existing.get("digest") == digest and existing.get("format") == FORMAT:
                self.lost_races += 1
                return False
        except Exception:
            pass
        shutil.rmtree(path, ignore_errors=True)
        ok = write_atomic_dir(path, arrays, manifest, replace=False)
        if not ok:
            self.lost_races += 1
        return ok

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "dir": str(self.dir),
            "entries": len(self),
            "hits": self.hits,
            "misses": self.misses,
            "corrupt_skipped": self.corrupt_skipped,
            "lost_races": self.lost_races,
        }
