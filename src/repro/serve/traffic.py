"""Synthetic service traffic: Poisson arrivals over a Zipf problem mix.

Models the ISSUE's request profile — many accelerator/CNN/folding variants
of the same underlying problems arriving concurrently — as a seeded,
reproducible workload:

* a corpus of random problems (optionally heterogeneous, i.e. carrying an
  OCM inventory so kind lanes are exercised);
* **Zipf-distributed popularity** over the corpus (rank-``r`` problem drawn
  with probability proportional to ``r**-zipf_a``) — hot problems repeat,
  which is what makes micro-batching, coalescing, and the result store
  earn their keep;
* **Poisson arrivals** at ``rate_hz`` (i.i.d. exponential gaps);
* a small seed pool per request, so duplicate fingerprints arrive both
  with equal seeds (dedup/coalesce/cache path) and different ones
  (distinct tasks that still share a micro-batch).

``run_traffic`` drives a :class:`repro.serve.PackingService` with the
workload under a client-concurrency bound and returns per-request records
plus throughput/latency summaries; ``verify_parity`` replays every unique
task through standalone ``pack()`` and bit-compares.  Shared by
``tools/serve_traffic.py`` (CLI / CI kill-restart lane) and
``benchmarks/bench_serve.py``.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass

import numpy as np

from ..core.api import pack
from ..core.problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
    PackingResult,
)
from .service import PackingService
from .stats import LatencyStats


def result_signature(res: PackingResult) -> tuple:
    """Canonical bit-parity signature of a packing result.

    Everything deterministic per (problem, seed, settings): packing, kind
    lanes, cost, convergence trace, iteration count.  Wall time is
    excluded — it is the one legitimately run-dependent field.
    """
    return (
        int(res.cost),
        tuple(tuple(b) for b in res.solution.bins),
        tuple(int(k) for k in res.solution.kinds),
        tuple(int(cost) for _, cost in res.trace),
        int(res.iterations),
    )


def make_problems(
    n: int, seed: int = 0, hetero: bool = False, max_buffers: int = 24
) -> list[PackingProblem]:
    """Seeded corpus of small random problems (the traffic's "model zoo")."""
    rng = np.random.default_rng(seed)
    probs = []
    for i in range(n):
        nb = int(rng.integers(2, max_buffers))
        bufs = [
            Buffer(
                width=int(rng.integers(1, 80)),
                depth=int(rng.integers(1, 40_000)),
                layer=int(rng.integers(0, 5)),
            )
            for _ in range(nb)
        ]
        ocm = (
            OCMInventory(
                (BRAM18, URAM288),
                (int(rng.integers(-1, 200)), int(rng.integers(-1, 64))),
                name=f"dev{i}",
            )
            if hetero
            else None
        )
        probs.append(
            PackingProblem(
                bufs, max_items=int(rng.integers(1, 6)), name=f"traffic{i}",
                ocm=ocm,
            )
        )
    return probs


@dataclass(frozen=True)
class Arrival:
    at_s: float  # offset from traffic start
    prob_idx: int
    seed: int


def make_workload(
    n_requests: int,
    n_problems: int,
    *,
    rate_hz: float = 200.0,
    zipf_a: float = 1.2,
    n_seeds: int = 2,
    seed: int = 0,
) -> list[Arrival]:
    """Seeded arrival schedule: Poisson timing, Zipf problem popularity."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    at = np.cumsum(gaps)
    ranks = np.arange(1, n_problems + 1, dtype=np.float64)
    popularity = ranks ** -zipf_a
    popularity /= popularity.sum()
    idx = rng.choice(n_problems, size=n_requests, p=popularity)
    seeds = rng.integers(0, n_seeds, size=n_requests)
    return [
        Arrival(float(a), int(i), int(s)) for a, i, s in zip(at, idx, seeds)
    ]


async def run_traffic(
    service: PackingService,
    problems: list[PackingProblem],
    workload: list[Arrival],
    *,
    concurrency: int = 32,
    deadline_ms: float | None = None,
    deadline_every: int = 0,
    on_response=None,
) -> dict:
    """Drive ``service`` with ``workload``; returns records + summary.

    Arrivals are held to their schedule (a client sleeps until its arrival
    offset), then bounded by ``concurrency`` in-flight clients.  With
    ``deadline_every=k`` every k-th request carries ``deadline_ms`` — the
    latency-sensitive slice of the traffic.  ``on_response(record)`` fires
    as each response lands (the kill-restart lane uses it to die mid-run).
    """
    sem = asyncio.Semaphore(concurrency)
    lat = LatencyStats()
    records: list[dict] = []
    t0 = service._clock()

    async def one(i: int, a: Arrival) -> None:
        delay = a.at_s - (service._clock() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        dl = (
            deadline_ms
            if deadline_ms is not None and deadline_every
            and i % deadline_every == 0
            else None
        )
        async with sem:
            sent = service._clock()
            res = await service.pack(
                problems[a.prob_idx], seed=a.seed, deadline_ms=dl
            )
            dt = service._clock() - sent
        lat.record(dt)
        rec = {
            "i": i,
            "arrival_s": a.at_s,
            "prob_idx": a.prob_idx,
            "seed": a.seed,
            "latency_s": dt,
            "deadline_ms": dl,
            "cost": int(res.cost),
        }
        records.append(rec)
        if on_response is not None:
            on_response(rec)

    await asyncio.gather(*(one(i, a) for i, a in enumerate(workload)))
    wall = service._clock() - t0
    return {
        "records": sorted(records, key=lambda r: r["i"]),
        "wall_s": wall,
        "rps": len(workload) / wall if wall > 0 else 0.0,
        "latency": lat.summary(),
    }


def verify_parity(
    service: PackingService,
    problems: list[PackingProblem],
    workload: list[Arrival],
    responses: dict[tuple[int, int], PackingResult] | None = None,
) -> dict:
    """Replay every unique (problem, seed) standalone and bit-compare.

    Compares against the service's memory/result-store state (or explicit
    ``responses`` keyed by ``(prob_idx, seed)``), using the same solver
    settings the service was built with.  Returns ``{"parity": bool,
    "tasks": n, "mismatches": [...]}`` — the hard flag BENCH_serve.json
    publishes.
    """
    unique = sorted({(a.prob_idx, a.seed) for a in workload})
    mismatches = []
    for idx, seed in unique:
        prob = problems[idx]
        if responses is not None:
            served = responses.get((idx, seed))
        else:
            key = service.task_key(prob, seed)
            served = service._results.get(key)
            if served is None and service.store is not None:
                served = service.store.get(key, prob)
        if served is None:
            mismatches.append({"prob_idx": idx, "seed": seed,
                               "error": "no served result"})
            continue
        ref = pack(
            prob,
            service.algorithm,
            seed=seed,
            max_seconds=service.max_seconds,
            intra_layer=service.intra_layer,
            backend=service.backend,
            **service.hyper,
        )
        if result_signature(served) != result_signature(ref):
            mismatches.append({"prob_idx": idx, "seed": seed,
                               "error": "signature mismatch"})
    return {
        "parity": not mismatches,
        "tasks": len(unique),
        "mismatches": mismatches,
    }
