from .rules import (  # noqa: F401
    batch_partition_specs,
    cache_partition_specs,
    dp_axes,
    opt_partition_specs,
    param_partition_specs,
    to_named,
)
