"""PartitionSpec rule tables: parameters, optimizer state, inputs, caches.

Conventions (GSPMD / pjit path — no shard_map, so non-divisible dimensions
are legal and padded by XLA; the roofline notes where padding costs):

* ``data`` (+ ``pod`` when present) — batch / token parallelism (DP).
* ``model`` — tensor parallelism: attention heads & d_ff & vocab; expert
  parallelism for MoE (expert dim); SSM inner channels.
* KV caches: batch over DP; heads over ``model`` when divisible, otherwise
  the cache *sequence* dim shards over ``model`` (ring-style decode reads).
* long_500k (batch=1): DP axes are idle for activations; caches/states shard
  over sequence/heads as available.
* ``prob`` — the bin-packing sweep axis (1-D ``launch.mesh.make_sweep_mesh``
  mesh): the fleet kernels' leading problem/row axis shards across devices,
  everything else (mode tables, kind tables) is replicated.  This axis goes
  through ``shard_map`` (not GSPMD), so callers pad the leading axis to a
  multiple of the mesh size first — ``prob_axis_spec`` below is the spec
  for those padded operands.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def prob_axis_spec(ndim: int) -> P:
    """Spec for a sweep-fleet operand: leading problem axis sharded over
    ``prob``, every trailing axis replicated."""
    return P("prob", *([None] * (ndim - 1)))


def _dp_size(mesh: Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# ------------------------------------------------------------------- params
def _param_spec(cfg: ModelConfig, path: str, ndim: int) -> P:
    """Spec for one (unstacked) parameter identified by its tree path."""
    leaf = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    if path == "embed":
        return P("model", None)  # vocab-sharded
    if parent == "lm_head":
        return P(None, "model")
    if path in ("dec_pos",):
        return P(None, None)
    # attention projections
    if parent in ("q", "k", "v"):
        return P(None, "model") if leaf == "kernel" else P("model")
    if parent == "o":
        return P("model", None) if leaf == "kernel" else P(None)
    # MLP
    if parent in ("up", "gate"):
        return P(None, "model") if leaf == "kernel" else P("model")
    if parent == "down":
        return P("model", None) if leaf == "kernel" else P(None)
    # MoE expert-parallel tables (E, d, f) / router
    if leaf == "router":
        return P(None, None)
    if leaf in ("up", "gate", "down") and ndim == 3:
        return P("model", None, None)
    # SSM mixer (per-stream projections: shard-aligned TP)
    if parent in ("in_proj", "z_proj", "x_proj", "b_proj", "c_proj", "dt_proj"):
        return P(None, "model") if leaf == "kernel" else P("model")
    if parent == "out_proj":
        return P("model", None) if leaf == "kernel" else P(None)
    if leaf in ("conv", "conv_x", "conv_b", "conv_c"):
        return P(None, "model")
    if leaf in ("conv_bias", "conv_x_bias", "conv_b_bias", "conv_c_bias",
                "a_log", "dt_bias", "d_skip", "norm_scale"):
        return P("model")
    # norms, qk-norm scales, branch norms, everything small: replicate
    return P(*([None] * ndim))


def _path_str(path) -> str:
    return "/".join(
        str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
    )


def param_partition_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> dict:
    """PartitionSpec pytree matching an (abstract) param tree.

    Leaves under stacked layer collections get a leading None for the layer
    dim.  MoE 3-D expert tables keep their own rule (detected by ndim).
    """

    def guard(spec: P, shape) -> P:
        """Drop axis assignments whose mesh size does not divide the dim
        (jit-boundary arrays must shard evenly; e.g. hymba's fused SSM
        in_proj width 6482 is not divisible by 16 — replicated, noted in
        EXPERIMENTS.md)."""
        fixed = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape.get(a, 1)
            fixed.append(ax if dim % size == 0 else None)
        return P(*fixed)

    def spec_for(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith(("layers/", "enc_layers/"))
        rel = ps.split("/", 1)[1] if stacked else ps
        ndim = len(leaf.shape) - (1 if stacked else 0)
        spec = _param_spec(cfg, rel, ndim)
        if stacked:
            spec = P(None, *spec)
        return guard(spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_partition_specs(cfg: ModelConfig, mesh: Mesh, opt_shape) -> dict:
    """Optimizer state: m/v mirror params; step is replicated."""
    param_like = {
        "m": param_partition_specs(cfg, mesh, opt_shape["m"]),
        "v": param_partition_specs(cfg, mesh, opt_shape["v"]),
        "step": P(),
    }
    return param_like


# ------------------------------------------------------------------- inputs
def batch_partition_specs(
    cfg: ModelConfig, mesh: Mesh, batch_shape: dict
) -> dict:
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        b = leaf.shape[0]
        batch_ax = dp if b % _dp_size(mesh) == 0 else ()
        rest = [None] * (len(leaf.shape) - 1)
        return P(batch_ax if batch_ax else None, *rest)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


# ------------------------------------------------------------------- caches
def cache_partition_specs(
    cfg: ModelConfig, mesh: Mesh, cache_shape: dict
) -> dict:
    dp = dp_axes(mesh)
    msize = _model_size(mesh)

    def spec_for(path, leaf):
        ps = _path_str(path)
        leafname = ps.split("/")[-1]
        if leafname in ("k", "v", "cross_k", "cross_v"):
            layers, b, t, hkv, dh = leaf.shape
            batch_ax = dp if b % _dp_size(mesh) == 0 else None
            if hkv % msize == 0:
                return P(None, batch_ax, None, "model", None)
            if batch_ax is None:
                # long-context single sequence: shard seq over everything
                return P(None, None, ("data", "model") if "data" in mesh.axis_names else "model", None, None)
            return P(None, batch_ax, "model", None, None)  # ring over seq
        if ps.endswith("ssm/state"):
            layers, b, h, p_, n = leaf.shape
            batch_ax = dp if b % _dp_size(mesh) == 0 else None
            head_ax = "model" if h % msize == 0 else None
            return P(None, batch_ax, head_ax, None, None)
        if ps.endswith("ssm/conv"):
            layers, b, k, c = leaf.shape
            batch_ax = dp if b % _dp_size(mesh) == 0 else None
            ch_ax = "model" if c % msize == 0 else None
            return P(None, batch_ax, None, ch_ax)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
