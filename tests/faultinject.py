"""Fault-injection helpers for the crash-safety tests (tests/test_resume.py).

Two crash families:

* **in-process**: :func:`crash_at` raises :class:`SimulatedCrash` (a
  ``BaseException``, so no ``except Exception`` handler can swallow it) from
  the ``on_checkpoint`` hook right after the Nth durable snapshot — the
  instant a real SIGKILL is most interesting, because the run has state on
  disk *and* state in flight;
* **out-of-process**: ``tools/sweep_resume.py --die-at-checkpoint N`` sends
  the process a genuine ``SIGKILL`` at the same point (used by the CI
  resume-smoke lane, where an actual dead process is the fixture).

Plus disk corruptors that damage the newest snapshot the way real crashes
do — a torn ``arrays.npz``, a scribbled ``manifest.json``, a half-deleted
step dir — so the tests can pin the degrade-to-newest-intact-checkpoint
contract of ``CheckpointManager.restore_latest_valid``.
"""
from __future__ import annotations

import json
from pathlib import Path


class SimulatedCrash(BaseException):
    """Raised by `crash_at` to model a SIGKILL at a checkpoint barrier."""


def crash_at(n: int):
    """An ``on_checkpoint`` hook that dies right after the Nth snapshot."""

    def hook(step: int) -> None:
        if step >= n:
            raise SimulatedCrash(f"simulated crash after checkpoint {step}")

    return hook


def latest_step_dir(ckpt_dir: str | Path) -> Path:
    """Newest complete ``step_XXXXXXXX`` dir under a checkpoint directory."""
    steps = sorted(
        p for p in Path(ckpt_dir).glob("step_*")
        if p.is_dir() and p.suffix != ".tmp"
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoint steps under {ckpt_dir}")
    return steps[-1]


def tear_arrays(step_dir: str | Path) -> None:
    """Truncate ``arrays.npz`` mid-file: a torn write / partial sector."""
    f = Path(step_dir) / "arrays.npz"
    blob = f.read_bytes()
    f.write_bytes(blob[: max(len(blob) // 2, 1)])


def corrupt_arrays(step_dir: str | Path) -> None:
    """Flip bytes inside ``arrays.npz`` (silent media corruption): the file
    stays full-length but no longer matches its manifest sha256."""
    f = Path(step_dir) / "arrays.npz"
    blob = bytearray(f.read_bytes())
    mid = len(blob) // 2
    blob[mid] ^= 0xFF
    f.write_bytes(bytes(blob))


def corrupt_manifest(step_dir: str | Path) -> None:
    """Scribble over ``manifest.json`` (crash mid-metadata-write)."""
    (Path(step_dir) / "manifest.json").write_text('{"truncated": tru')


def half_delete(step_dir: str | Path) -> None:
    """Remove ``arrays.npz`` but keep the dir (crash mid-GC)."""
    (Path(step_dir) / "arrays.npz").unlink()
