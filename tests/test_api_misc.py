"""API surface, error handling, result bookkeeping."""
import numpy as np
import pytest

import repro.core as c
from repro.core.problem import BRAMSpec, Buffer, PackingProblem


def test_unknown_algorithm_raises():
    prob = c.get_problem("CNV-W1A1")
    with pytest.raises(ValueError):
        c.pack(prob, "quantum-annealing")


def test_unknown_accelerator_raises():
    with pytest.raises(KeyError):
        c.get_problem("ResNet-9000")


def test_empty_problem_rejected():
    with pytest.raises(ValueError):
        PackingProblem([])
    with pytest.raises(ValueError):
        PackingProblem([Buffer(1, 1, 0)], max_items=0)


def test_invalid_solution_detected():
    prob = c.get_problem("CNV-W1A1")
    sol = prob.singleton_solution()
    sol.bins[0].append(sol.bins[1][0])  # duplicate placement
    with pytest.raises(ValueError):
        sol.validate()
    assert not sol.is_valid()


def test_packing_result_bookkeeping():
    prob = c.get_problem("CNV-W2A2")
    r = c.pack(prob, "ffd")
    assert r.baseline_cost == prob.baseline_cost()
    assert r.delta_bram == pytest.approx(r.baseline_cost / r.cost)
    assert "FFD".lower() in r.algorithm
    assert "eff" in r.summary()


def test_custom_bram_spec():
    """A single-mode RAM (e.g. a 512x36 URAM-style primitive) works."""
    spec = BRAMSpec(modes=((72, 4096),), capacity_bits=72 * 4096)
    prob = PackingProblem(
        [Buffer(72, 100, 0), Buffer(36, 4000, 1)], bram=spec
    )
    sol = prob.singleton_solution()
    assert sol.cost() == 2
    assert prob.lower_bound() >= 1


def test_report_cli_runs(capsys):
    from repro.launch import report

    report.main([])
    out = capsys.readouterr().out
    assert "cells ok" in out
