"""Bench-rot smoke tests: every ``benchmarks/bench_*.py`` entry point runs.

The bench modules used to have zero coverage (``bench_fig45``,
``bench_table3``, ``bench_table4``, ``bench_roofline`` in particular) and
could rot unnoticed.  The quick tests below exercise the previously
uncovered modules directly at smoke scale; the slow test drives
``benchmarks/run.py --smoke``, which executes EVERY bench entry point in
well under a minute (also wired into CI as its own lane).  These are
execution checks, not measurements — CSVs land in ``benchmarks/out/``.
"""
import sys
from pathlib import Path

import pytest

# the benchmarks package lives at the repo root, next to src/
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def test_bench_fig45_smoke():
    from benchmarks import bench_fig45

    rows = bench_fig45.run(budget_s=0.3, seeds=(0,))
    assert len(rows) == len(bench_fig45.POPS)
    assert all(r[1] > 0 for r in rows)  # best BRAM cost per population size


def test_bench_table3_smoke():
    from benchmarks import bench_table3

    rows = bench_table3.run(
        accelerators=["CNV-W1A1"], budgets={"CNV-W1A1": 1}, seeds=(0,)
    )
    assert {r[1] for r in rows} == set(bench_table3.ALGOS)
    assert all(r[2] > 0 for r in rows)


def test_bench_table4_smoke():
    from benchmarks import bench_table4

    rows = bench_table4.run(accelerators=["CNV-W1A1"], budgets={"CNV-W1A1": 1})
    assert [r[1] for r in rows] == ["baseline", "intra", "inter"]
    # packed never beats the lower bound, never loses to the baseline
    base, intra, inter = rows
    assert inter[2] <= base[2] and intra[2] <= base[2]


def test_bench_roofline_smoke():
    from benchmarks import bench_roofline

    # without dry-run artifacts this is the empty-report path; with them it
    # must parse every JSON — either way it runs end to end
    rows = bench_roofline.run()
    assert isinstance(rows, list)


# wall-budgeted on purpose (the bench measures throughput, not a pinned
# trajectory) — un-promote the truncation warning pytest.ini turns into an error
@pytest.mark.filterwarnings("default:.*NOT seed-reproducible.*:RuntimeWarning")
def test_bench_portfolio_smoke():
    from benchmarks import bench_engine

    rows = bench_engine.run_portfolio(smoke=True, budget_s=0.5)
    assert [r[2] for r in rows] == ["threads", "fleet"] * 4
    assert [r[1] for r in rows[::2]] == [
        "sa-fleet", "mixed", "ga-heavy", "scalar-heavy"
    ]


def test_bench_racing_smoke():
    from benchmarks import bench_racing
    from benchmarks.common import OUT_DIR

    rows = bench_racing.run(smoke=True)
    assert len(rows) == 1  # one accelerator at smoke scale
    name, budget, spent, auto_cost, default_cost = rows[0][:5]
    assert name == "CNV-W1A1"
    assert 0 < spent <= budget  # the race ledger is a hard cap
    assert auto_cost > 0 and default_cost > 0
    assert (OUT_DIR / "BENCH_racing.json").is_file()


def test_bench_serve_smoke():
    from benchmarks import bench_serve
    from benchmarks.common import OUT_DIR

    record = bench_serve.run(smoke=True)
    # the hard gates already ran inside run(); pin the published record
    assert record["bit_parity"] is True
    assert record["warm_solved"] == 0
    assert record["warm"]["rps"] > record["cold"]["rps"]
    assert (OUT_DIR / "BENCH_serve.json").is_file()
    assert (OUT_DIR / "serve_latency.csv").is_file()


@pytest.mark.slow
@pytest.mark.filterwarnings("default:.*NOT seed-reproducible.*:RuntimeWarning")
def test_bench_run_smoke_executes_every_module():
    """`python -m benchmarks.run --smoke` completes every bench entry point
    (the anti-rot lane; ~25 s total on the CI host)."""
    from benchmarks import run as bench_run

    bench_run.main(["--smoke"])
