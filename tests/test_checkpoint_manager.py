"""Direct coverage of checkpoint/manager.py: atomicity leftovers, bf16
round-trips, keep_n GC, integrity-failure fallback, and async-write error
surfacing (the crash-safety substrate of docs/DESIGN.md section 12)."""
import numpy as np
import pytest

import jax.numpy as jnp
from faultinject import (
    corrupt_arrays,
    corrupt_manifest,
    half_delete,
    latest_step_dir,
    tear_arrays,
)
from repro.checkpoint import CheckpointManager


def _state(step: int) -> dict:
    return {"w": np.arange(6, dtype=np.float32) + step, "b": np.int64(step)}


def test_leftover_tmp_dir_is_replaced_and_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    stale = tmp_path / "step_00000001.tmp"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"torn half-write")
    mgr.save(1, _state(1))
    assert mgr.all_steps() == [1]
    assert not stale.exists()  # the atomic rename consumed the retry's tmp
    step, st, _ = mgr.restore(_state(0))
    assert step == 1
    np.testing.assert_array_equal(st["w"], _state(1)["w"])


def test_bf16_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    ref = jnp.asarray([1.5, -2.25, 3e-3, 65504.0], dtype=jnp.bfloat16)
    mgr.save(1, {"x": ref})
    _, st, _ = mgr.restore({"x": jnp.zeros(4, dtype=jnp.bfloat16)})
    assert st["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(st["x"]).view(np.uint16), np.asarray(ref).view(np.uint16)
    )


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    for s in range(1, 6):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [4, 5]
    assert mgr.latest_step() == 5


def test_all_steps_ignores_tmp_half_deleted_and_stray(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=0, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, _state(s))
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_bogus").mkdir()
    half_delete(tmp_path / "step_00000002")  # arrays.npz gone, dir remains
    assert mgr.all_steps() == [1, 3]


@pytest.mark.parametrize(
    "damage", [tear_arrays, corrupt_arrays, corrupt_manifest, half_delete]
)
def test_restore_falls_back_to_newest_intact_step(tmp_path, damage):
    mgr = CheckpointManager(tmp_path, keep_n=0, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, _state(s))
    damage(latest_step_dir(tmp_path))
    step, st, _ = mgr.restore(_state(0))  # step=None -> latest valid
    assert step == 2
    np.testing.assert_array_equal(st["w"], _state(2)["w"])


def test_restore_latest_valid_flat_mode(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state(1), extra={"kind": "test"})
    mgr.save(2, _state(2), extra={"kind": "test2"})
    corrupt_manifest(latest_step_dir(tmp_path))
    step, flat, extra = mgr.restore_latest_valid()  # like=None: raw dict
    assert step == 1 and extra == {"kind": "test"}
    np.testing.assert_array_equal(flat["w"], _state(1)["w"])


def test_every_step_damaged_raises_ioerror(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=0, async_save=False)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    corrupt_arrays(tmp_path / "step_00000001")
    tear_arrays(tmp_path / "step_00000002")
    with pytest.raises(IOError):
        mgr.restore(_state(0))


def test_no_steps_raises_filenotfound(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(0))


def test_explicit_step_still_raises_on_corruption(tmp_path):
    # callers pinning a step opt out of the fallback: corruption must raise
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state(1))
    corrupt_arrays(tmp_path / "step_00000001")
    with pytest.raises(IOError):
        mgr.restore(_state(0), step=1)


def test_async_write_failure_surfaces_on_next_save(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_save=True)
    blocker = tmp_path / "blocker"
    blocker.write_text("a file where the checkpoint dir should be")
    mgr.dir = blocker / "sub"  # forces the background _write to fail
    mgr.save(1, _state(1))  # enqueues; the failure lands in the background
    with pytest.raises(OSError):
        mgr.save(2, _state(2))  # surfaces the previous write's exception
    mgr.dir = tmp_path / "ck"  # healthy again: save 2 was re-raised, not kept
    mgr.save(3, _state(3))
    mgr.wait()
    assert mgr.all_steps() == [3]


def test_wait_reraises_background_failure_once(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_save=True)
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    mgr.dir = blocker / "sub"
    mgr.save(1, _state(1))
    with pytest.raises(OSError):
        mgr.wait()
    mgr.wait()  # the error was consumed; a second wait is clean
