"""Algorithm behaviour vs the paper's Table 3/4 results."""
import pytest

import repro.core as c


@pytest.mark.slow
@pytest.mark.parametrize("algo", ["nfd", "ffd", "next-fit", "ga-nfd", "sa-nfd", "ga-s", "sa-s"])
def test_all_algorithms_valid_and_improve(algo):
    prob = c.get_problem("CNV-W1A1")
    hp = c.hyperparams("CNV-W1A1")
    r = c.pack(prob, algo, seed=0, max_seconds=4, **hp)
    r.solution.validate()
    assert r.cost <= prob.baseline_cost()
    assert prob.lower_bound() <= r.cost


@pytest.mark.slow
@pytest.mark.parametrize("name", ["CNV-W1A1", "CNV-W2A2", "Tincy-YOLO"])
def test_ga_nfd_matches_paper_quality(name):
    """GA-NFD should reach (or beat — our baseline mode choice is freer)
    the paper's inter-layer packed BRAM count within 3%."""
    prob = c.get_problem(name)
    hp = c.hyperparams(name)
    r = c.pack(prob, "ga-nfd", seed=0, max_seconds=15, **hp)
    paper_inter = c.PAPER_TABLE4[name][4]
    assert r.cost <= paper_inter * 1.03, f"{name}: {r.cost} vs paper {paper_inter}"


@pytest.mark.slow
def test_intra_layer_constraint_enforced():
    prob = c.get_problem("CNV-W1A1")
    r = c.pack(prob, "ga-nfd", seed=0, max_seconds=5, intra_layer=True)
    r.solution.validate(intra_layer=True)
    # paper: intra costs at most ~10% over inter
    r_inter = c.pack(prob, "ga-nfd", seed=0, max_seconds=5)
    assert r.cost >= r_inter.cost  # constraint can't help
    assert r.cost <= r_inter.cost * 1.15


def test_cardinality_respected_all_algorithms():
    prob = c.get_problem("CNV-W2A2", max_items=2)
    for algo in ("nfd", "ffd", "ga-nfd", "sa-nfd"):
        r = c.pack(prob, algo, seed=1, max_seconds=2)
        assert r.solution.max_items_per_bin() <= 2


def test_convergence_trace_monotone():
    prob = c.get_problem("Tincy-YOLO")
    r = c.pack(prob, "sa-nfd", seed=0, max_seconds=3)
    costs = [cost for _, cost in r.trace]
    assert all(a >= b for a, b in zip(costs, costs[1:]))
    assert r.time_to_within(0.01) <= r.wall_time_s
