"""The BRAM model reproduces the paper's published numbers (Table 4)."""
import numpy as np
import pytest

import repro.core as c

# (accelerator, paper baseline BRAM, paper baseline efficiency %)
PAPER_BASELINES = [
    ("CNV-W1A1", 120, 69.3),
    ("CNV-W2A2", 208, 79.9),
    ("DoReFaNet", 4116, 78.8),
    ("ReBNet", 2880, 64.1),
    ("RN50-W1A2", 2064, 57.9),
    ("RN101-W1A2", 4240, 52.4),
    ("RN152-W1A2", 5904, 50.9),
]


@pytest.mark.parametrize("name,paper_bram,paper_eff", PAPER_BASELINES)
def test_total_bits_match_paper_baseline_efficiency(name, paper_bram, paper_eff):
    """bits / (paper_baseline_BRAM * 18Kib) must equal the paper's baseline
    efficiency — validates our Table 1 transcription + Eq. 1 bit accounting."""
    prob = c.get_problem(name)
    eff = prob.total_bits / (paper_bram * c.BRAM18_CAPACITY_BITS) * 100
    assert eff == pytest.approx(paper_eff, abs=0.75), (
        f"{name}: computed {eff:.2f}% vs paper {paper_eff}%"
    )


def test_buffer_counts_match_table1():
    expected = {
        "CNV-W1A1": 43, "CNV-W2A2": 28, "Tincy-YOLO": 137,
        "DoReFaNet": 320, "ReBNet": 552, "RN50-W1A2": 896,
    }
    for name, n in expected.items():
        assert c.get_problem(name).n == n


def test_bin_cost_brute_force():
    prob = c.get_problem("CNV-W1A1")
    rng = np.random.default_rng(1)
    for _ in range(200):
        w = int(rng.integers(1, 100))
        h = int(rng.integers(1, 100_000))
        expect = min(
            -(-w // mw) * -(-h // md) for mw, md in c.BRAM18_MODES
        )
        assert prob.bin_cost(w, h) == expect


def test_baseline_is_singleton_cost():
    for name in ("CNV-W1A1", "ReBNet"):
        prob = c.get_problem(name)
        assert prob.singleton_solution().cost() == prob.baseline_cost()


def test_lower_bound_below_everything():
    for name in c.ACCELERATORS:
        prob = c.get_problem(name)
        assert prob.lower_bound() <= prob.baseline_cost()
        paper_inter = c.PAPER_TABLE4[name][4]
        assert prob.lower_bound() <= paper_inter


def test_grid_gap_properties():
    prob = c.get_problem("CNV-W1A1")
    for w, h in [(32, 100), (1, 8192), (64, 513)]:
        gap = prob.grid_gap(w, h)
        mw, md = prob.bin_mode(w, h)
        assert 0 <= gap < md
