"""Hypothesis property tests on the packing invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dependency: hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as c
from repro.core.nfd import nfd_from_scratch, nfd_repack
from repro.core.ga import buffer_swap


@st.composite
def problems(draw):
    n = draw(st.integers(2, 60))
    widths = draw(st.lists(st.integers(1, 80), min_size=n, max_size=n))
    depths = draw(st.lists(st.integers(1, 40_000), min_size=n, max_size=n))
    layers = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    max_items = draw(st.integers(1, 6))
    bufs = [
        c.Buffer(width=w, depth=d, layer=l)
        for w, d, l in zip(widths, depths, layers)
    ]
    return c.PackingProblem(bufs, max_items=max_items)


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_nfd_from_scratch_valid(prob, seed):
    rng = np.random.default_rng(seed)
    sol = nfd_from_scratch(prob, rng, p_adm_h=0.2)
    sol.validate()
    assert prob.lower_bound() <= sol.cost()
    eff = sol.efficiency()
    assert 0.0 < eff <= 1.0


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_nfd_repack_preserves_validity(prob, seed):
    rng = np.random.default_rng(seed)
    sol = prob.singleton_solution()
    for _ in range(4):
        sol = nfd_repack(sol, rng, threshold=0.9, extra_frac=0.1, p_adm_h=0.3)
        sol.validate()


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_buffer_swap_preserves_validity(prob, seed):
    rng = np.random.default_rng(seed)
    sol = nfd_from_scratch(prob, rng)
    for _ in range(4):
        sol = buffer_swap(sol, rng, n_moves=3)
        sol.validate()


@settings(max_examples=30, deadline=None)
@given(problems())
def test_singleton_cost_additive(prob):
    sol = prob.singleton_solution()
    per = [prob.bin_cost(int(prob.widths[i]), int(prob.depths[i])) for i in range(prob.n)]
    assert sol.cost() == sum(per)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 80), st.integers(1, 30_000), st.integers(1, 30_000)
)
def test_same_width_stack_subadditive_per_mode(w, h1, h2):
    """Within any FIXED aspect mode, stacking same-width buffers never costs
    more than separate bins (ceil subadditivity).  The *cross-mode* claim is
    FALSE — hypothesis found w=37, h1=1, h2=2048, where the parts prefer
    different modes and stacking loses a BRAM; that is precisely why NFD
    admits a buffer only when the grid gap shrinks."""
    from repro.core.problem import BRAM18_MODES

    prob = c.PackingProblem([c.Buffer(w, h1, 0), c.Buffer(w, h2, 0)])
    stacked_cost = prob.bin_cost(w, h1 + h2)
    for mw, md in BRAM18_MODES:
        per_mode = (-(-w // mw)) * (-(-h1 // md)) + (-(-w // mw)) * (-(-h2 // md))
        assert stacked_cost <= per_mode


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(8, 512), min_size=1, max_size=60), st.integers(1, 8))
def test_sequence_packing_invariants(doc_lengths, card):
    from repro.data import pack_documents

    seq_len = 512
    seqs = pack_documents(doc_lengths, seq_len, max_docs_per_seq=card)
    placed = sorted(i for s in seqs for i in s)
    assert placed == list(range(len(doc_lengths)))
    for s in seqs:
        assert sum(doc_lengths[i] for i in s) <= seq_len
        assert len(s) <= card
