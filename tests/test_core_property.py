"""Hypothesis property tests on the packing invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dependency: hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as c
from repro.core.nfd import nfd_from_scratch, nfd_repack
from repro.core.ga import buffer_swap


@st.composite
def problems(draw):
    n = draw(st.integers(2, 60))
    widths = draw(st.lists(st.integers(1, 80), min_size=n, max_size=n))
    depths = draw(st.lists(st.integers(1, 40_000), min_size=n, max_size=n))
    layers = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    max_items = draw(st.integers(1, 6))
    bufs = [
        c.Buffer(width=w, depth=d, layer=l)
        for w, d, l in zip(widths, depths, layers)
    ]
    return c.PackingProblem(bufs, max_items=max_items)


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_nfd_from_scratch_valid(prob, seed):
    rng = np.random.default_rng(seed)
    sol = nfd_from_scratch(prob, rng, p_adm_h=0.2)
    sol.validate()
    assert prob.lower_bound() <= sol.cost()
    eff = sol.efficiency()
    assert 0.0 < eff <= 1.0


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_nfd_repack_preserves_validity(prob, seed):
    rng = np.random.default_rng(seed)
    sol = prob.singleton_solution()
    for _ in range(4):
        sol = nfd_repack(sol, rng, threshold=0.9, extra_frac=0.1, p_adm_h=0.3)
        sol.validate()


@settings(max_examples=40, deadline=None)
@given(problems(), st.integers(0, 10_000))
def test_buffer_swap_preserves_validity(prob, seed):
    rng = np.random.default_rng(seed)
    sol = nfd_from_scratch(prob, rng)
    for _ in range(4):
        sol = buffer_swap(sol, rng, n_moves=3)
        sol.validate()


@settings(max_examples=30, deadline=None)
@given(problems())
def test_singleton_cost_additive(prob):
    sol = prob.singleton_solution()
    per = [prob.bin_cost(int(prob.widths[i]), int(prob.depths[i])) for i in range(prob.n)]
    assert sol.cost() == sum(per)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 80), st.integers(1, 30_000), st.integers(1, 30_000)
)
def test_same_width_stack_subadditive_per_mode(w, h1, h2):
    """Within any FIXED aspect mode, stacking same-width buffers never costs
    more than separate bins (ceil subadditivity).  The *cross-mode* claim is
    FALSE — hypothesis found w=37, h1=1, h2=2048, where the parts prefer
    different modes and stacking loses a BRAM; that is precisely why NFD
    admits a buffer only when the grid gap shrinks."""
    from repro.core.problem import BRAM18_MODES

    prob = c.PackingProblem([c.Buffer(w, h1, 0), c.Buffer(w, h2, 0)])
    stacked_cost = prob.bin_cost(w, h1 + h2)
    for mw, md in BRAM18_MODES:
        per_mode = (-(-w // mw)) * (-(-h1 // md)) + (-(-w // mw)) * (-(-h2 // md))
        assert stacked_cost <= per_mode


@st.composite
def kind_tables_strategy(draw):
    """1-3 RAM kinds, each with a random mode set and an integer weight."""
    n_kinds = draw(st.integers(1, 3))
    tables = []
    for _ in range(n_kinds):
        n_modes = draw(st.integers(1, 6))
        modes = tuple(
            (draw(st.integers(1, 96)), draw(st.integers(1, 40_000)))
            for _ in range(n_modes)
        )
        tables.append((draw(st.integers(1, 32)), modes))
    return tuple(tables)


@settings(max_examples=30, deadline=None)
@given(kind_tables_strategy(), st.integers(0, 10_000))
def test_random_mode_sets_backends_agree(kind_tables, seed):
    """python/ref/pallas(interpret)/legacy cost evaluators agree on *random*
    RAM mode sets (not just BRAM18), including weights and empty slots."""
    import jax.numpy as jnp

    from repro.kernels.binpack_fitness.kernel import binpack_fitness_kinds_pallas
    from repro.kernels.binpack_fitness.ref import binpack_fitness_kinds_ref
    from repro.kernels.binpack_sa_step.ops import _bin_costs_kinds_numpy

    rng = np.random.default_rng(seed)
    p, nb = int(rng.integers(1, 5)), int(rng.integers(1, 40))
    w = rng.integers(0, 100, (p, nb)).astype(np.int32)
    h = np.where(w > 0, rng.integers(1, 60_000, (p, nb)), 0).astype(np.int32)
    k = rng.integers(0, len(kind_tables), (p, nb)).astype(np.int32)
    # legacy: scalar min-over-modes loop, the seed's formulation
    legacy = np.zeros((p, nb), dtype=np.int64)
    for i in range(p):
        for j in range(nb):
            if w[i, j] > 0:
                weight, modes = kind_tables[int(k[i, j])]
                legacy[i, j] = weight * min(
                    -(-int(w[i, j]) // mw) * -(-int(h[i, j]) // md)
                    for mw, md in modes
                )
    python = _bin_costs_kinds_numpy(w, h, k, kind_tables)
    ref = np.asarray(
        binpack_fitness_kinds_ref(jnp.asarray(w), jnp.asarray(h),
                                  jnp.asarray(k), kind_tables)
    )
    pallas = np.asarray(
        binpack_fitness_kinds_pallas(jnp.asarray(w), jnp.asarray(h),
                                     jnp.asarray(k), kind_tables, True)
    )
    np.testing.assert_array_equal(python, legacy)
    np.testing.assert_array_equal(ref, legacy)
    np.testing.assert_array_equal(pallas, legacy)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(8, 512), min_size=1, max_size=60), st.integers(1, 8))
def test_sequence_packing_invariants(doc_lengths, card):
    from repro.data import pack_documents

    seq_len = 512
    seqs = pack_documents(doc_lengths, seq_len, max_docs_per_seq=card)
    placed = sorted(i for s in seqs for i in s)
    assert placed == list(range(len(doc_lengths)))
    for s in seqs:
        assert sum(doc_lengths[i] for i in s) <= seq_len
        assert len(s) <= card


@st.composite
def problem_fleets(draw):
    """Randomly sized fleets sharing one cost model (single- or two-kind)."""
    from repro.core.problem import BRAM18, URAM288, OCMInventory

    hetero = draw(st.booleans())
    fleet = []
    for _ in range(draw(st.integers(1, 5))):
        n = draw(st.integers(1, 25))
        bufs = [
            c.Buffer(
                width=draw(st.integers(1, 80)),
                depth=draw(st.integers(1, 40_000)),
                layer=draw(st.integers(0, 4)),
            )
            for _ in range(n)
        ]
        ocm = (
            OCMInventory(
                (BRAM18, URAM288),
                (draw(st.integers(-1, 500)), draw(st.integers(-1, 64))),
            )
            if hetero
            else None
        )
        fleet.append(
            c.PackingProblem(bufs, max_items=draw(st.integers(1, 6)), ocm=ocm)
        )
    return fleet


@settings(max_examples=40, deadline=None)
@given(problem_fleets())
def test_problem_batch_codec_round_trip(fleet):
    """encode_problem_batch/decode_problem_batch round-trips arbitrary
    fleets: geometry, layers, cardinality, kinds, counts, fingerprints."""
    from repro.core.problem import decode_problem_batch, encode_problem_batch

    batch = encode_problem_batch(fleet)
    assert batch.size == len(fleet)
    assert batch.n_max == max(p.n for p in fleet)
    back = decode_problem_batch(batch)
    for a, b in zip(fleet, back):
        np.testing.assert_array_equal(a.widths, b.widths)
        np.testing.assert_array_equal(a.depths, b.depths)
        np.testing.assert_array_equal(a.layers, b.layers)
        assert a.max_items == b.max_items
        assert a.kind_tables == b.kind_tables
        assert a.kind_counts == b.kind_counts
        assert a.fingerprint() == b.fingerprint()
