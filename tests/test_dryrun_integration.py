"""Integration: the production-mesh dry-run results (deliverable e).

Reads the cached sweep results if present; otherwise compiles one small
cell in a subprocess (fresh interpreter so the 512-device XLA flag never
leaks into this test process).
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"


def _cells():
    from repro.configs import ARCHS, shape_cells

    out = []
    for arch in ARCHS:
        for shape in shape_cells(arch):
            for pods in ("pod1", "pod2"):
                out.append((arch, shape, pods))
    return out


@pytest.mark.skipif(not DRYRUN.exists(), reason="sweep not run yet")
def test_all_cached_cells_ok():
    cells = _cells()
    assert len(cells) == 64
    missing, failed = [], []
    for arch, shape, pods in cells:
        f = DRYRUN / f"{arch}__{shape}__{pods}.json"
        if not f.exists():
            missing.append(f.name)
            continue
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            failed.append(f.name)
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


@pytest.mark.skipif(not DRYRUN.exists(), reason="sweep not run yet")
def test_roofline_terms_present_and_positive():
    for f in DRYRUN.glob("*.json"):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        t = r["roofline"]
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert r["flops_per_device"] > 0


@pytest.mark.slow
def test_fresh_compile_one_cell(tmp_path):
    """Compile qwen3-0.6b decode on the 256-chip mesh from scratch."""
    code = (
        "from repro.launch.dryrun import lower_cell\n"
        "l, c, m = lower_cell('qwen3-0.6b', 'decode_32k', False)\n"
        "print('COMPILED', m['n_devices'])\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert "COMPILED 256" in out.stdout, out.stderr[-2000:]
