"""Cross-problem batched DSE solver: batch codecs, fleet-vs-standalone
bit parity, sweep dedup/cache, and the sweep report.

The load-bearing contract: every candidate in a `pack_sweep` fleet consumes
its own RNG stream inside the batched engines, so its result is
bit-identical to the standalone `pack(...)` run with the same seed and
budgets — batching buys throughput, never different answers.
"""
import numpy as np
import pytest

import repro.core as c
from repro.core.problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
    batch_group_key,
    decode_problem_batch,
    encode_problem_batch,
)
from repro.core.sa import SimulatedAnnealingPacker


def random_problem(rng, hetero=False):
    n = int(rng.integers(2, 40))
    bufs = [
        Buffer(
            width=int(rng.integers(1, 80)),
            depth=int(rng.integers(1, 40_000)),
            layer=int(rng.integers(0, 5)),
        )
        for _ in range(n)
    ]
    ocm = (
        OCMInventory(
            (BRAM18, URAM288),
            (int(rng.integers(-1, 200)), int(rng.integers(-1, 64))),
            name=f"dev{int(rng.integers(100))}",
        )
        if hetero
        else None
    )
    return PackingProblem(
        bufs,
        max_items=int(rng.integers(1, 6)),
        name=f"rp{n}",
        ocm=ocm,
    )


# ------------------------------------------------------------- batch codecs
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("hetero", [False, True])
def test_problem_batch_round_trip(seed, hetero):
    """Seeded random fleets (varying n / max_items / inventory counts)
    round-trip through the (NB, max_items) envelope codec exactly."""
    rng = np.random.default_rng(seed)
    probs = [random_problem(rng, hetero=hetero) for _ in range(int(rng.integers(1, 7)))]
    if hetero:
        # counts vary per problem but kinds/mode tables are shared
        assert len({batch_group_key(p) for p in probs}) == 1
    batch = encode_problem_batch(probs)
    assert batch.size == len(probs)
    assert batch.n_max == max(p.n for p in probs)
    back = decode_problem_batch(batch)
    for a, b in zip(probs, back):
        np.testing.assert_array_equal(a.widths, b.widths)
        np.testing.assert_array_equal(a.depths, b.depths)
        np.testing.assert_array_equal(a.layers, b.layers)
        assert a.max_items == b.max_items
        assert a.kind_tables == b.kind_tables
        assert a.kind_counts == b.kind_counts
        assert a.name == b.name
        assert (a.ocm is None) == (b.ocm is None)
        assert a.fingerprint() == b.fingerprint()
        # the decoded problem is solver-equivalent: same costs everywhere
        assert a.bin_cost(36, 1024) == b.bin_cost(36, 1024)


def test_problem_batch_masks_and_tables():
    p1 = c.get_problem("CNV-W1A1")
    p2 = c.get_problem("CNV-W2A2", max_items=3)
    batch = encode_problem_batch([p1, p2])
    assert batch.cap_max == 4
    np.testing.assert_array_equal(batch.n, [p1.n, p2.n])
    assert batch.mask[1, p2.n :].sum() == 0 and batch.mask[1, : p2.n].all()
    assert (batch.widths[1, p2.n :] == 0).all()
    wext, dext, lext = batch.ext_tables()
    assert wext.shape == (2, batch.n_max + 1)
    assert wext[0, -1] == dext[0, -1] == 0 and lext[0, -1] == -1


def test_problem_batch_rejects_mixed_cost_models():
    p1 = c.get_problem("CNV-W1A1")
    h1 = c.get_problem("CNV-W1A1", device="U50")
    assert batch_group_key(p1) != batch_group_key(h1)
    with pytest.raises(ValueError):
        encode_problem_batch([p1, h1])
    with pytest.raises(ValueError):
        encode_problem_batch([])


def test_fingerprint_ignores_names_not_structure():
    rows = c.TABLE1_ROWS["CNV-W1A1"]
    a = PackingProblem(c.buffers_from_shape_rows(rows), name="one")
    b = PackingProblem(c.buffers_from_shape_rows(rows), name="two")
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != PackingProblem(
        c.buffers_from_shape_rows(rows), max_items=3
    ).fingerprint()
    assert a.fingerprint() != c.get_problem("CNV-W1A1", device="U50").fingerprint()


# ------------------------------------------------- fleet-vs-standalone parity
_SA_KW = dict(max_seconds=1e9, patience=10**9, max_iterations=250,
              backend="python")


def _standalone_sa(prob, seed, n_chains=4, **kw):
    merged = {**_SA_KW, **kw}
    return c.pack(prob, "sa-s", seed=seed, n_chains=n_chains, **merged)


def test_sweep_singleton_bit_identical_to_pack():
    """The acceptance pin: a one-candidate sweep IS pack(), bit for bit."""
    prob = c.get_problem("CNV-W1A1")
    sw = c.pack_sweep([prob], "sa-s", seed=7, n_chains=4, **_SA_KW)
    ref = _standalone_sa(prob, 7)
    r = sw.results[0]
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace]
    assert r.iterations == ref.iterations
    assert r.params["seed"] == 7


def test_sweep_fleet_matches_standalone_per_problem():
    """Mixed sizes + max_items in one batch: every candidate reproduces its
    standalone trajectory (per-problem RNG streams)."""
    probs = [
        c.get_problem("CNV-W1A1"),
        c.get_problem("CNV-W2A2", max_items=3),
        c.get_problem("Tincy-YOLO"),
    ]
    seeds = [3, 4, 5]
    sw = c.pack_sweep(probs, "sa-s", seeds=seeds, n_chains=3, **_SA_KW)
    assert sw.n_groups == 1  # one shared cost model -> one batched group
    for r, prob, s in zip(sw.results, probs, seeds):
        ref = _standalone_sa(prob, s, n_chains=3)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins, prob.name
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace]
        r.solution.validate()
        assert r.solution.cost() == r.solution.cost_full() == r.cost


def test_sweep_hetero_fleet_mixed_devices():
    """ZU7EV and U50 share kind tables but not counts: one group, exact
    per-problem inventory penalties, parity incl. kind lanes."""
    probs = [
        c.get_problem("CNV-W1A1", device="ZU7EV"),
        c.get_problem("CNV-W2A2", device="U50"),
    ]
    sw = c.pack_sweep(probs, "sa-s", seeds=[1, 2], n_chains=3, **_SA_KW)
    assert sw.n_groups == 1
    for r, prob, s in zip(sw.results, probs, [1, 2]):
        ref = _standalone_sa(prob, s, n_chains=3)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins
        assert list(r.solution.kinds) == list(ref.solution.kinds)
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace]


def test_sweep_mixed_cost_models_split_groups():
    probs = [
        c.get_problem("CNV-W1A1"),
        c.get_problem("CNV-W1A1", device="U50"),
        c.get_problem("CNV-W2A2"),
    ]
    sw = c.pack_sweep(probs, "sa-s", seeds=[0, 1, 2], n_chains=3, **_SA_KW)
    assert sw.n_groups == 2  # single-kind group + hetero group
    for r, prob, s in zip(sw.results, probs, [0, 1, 2]):
        ref = _standalone_sa(prob, s, n_chains=3)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins


def test_sweep_intra_layer_and_freezing_parity():
    """Patience small enough to freeze problems early: frozen problems stop
    consuming RNG exactly where the standalone run stops."""
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    kw = dict(max_seconds=1e9, patience=40, max_iterations=400,
              backend="python")
    sw = c.pack_sweep(probs, "sa-s", seeds=[0, 8], n_chains=3,
                      intra_layer=True, **kw)
    for r, prob, s in zip(sw.results, probs, [0, 8]):
        ref = c.pack(prob, "sa-s", seed=s, n_chains=3, intra_layer=True, **kw)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins
        assert r.iterations == ref.iterations  # froze at the same step
        r.solution.validate(intra_layer=True)


def test_sweep_ga_lockstep_matches_standalone():
    """The lockstep GA driver stacks all problems' generation fitness into
    one (P, n_pop, NB) kernel call without forking any trajectory."""
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    kw = dict(max_seconds=1e9, patience=10**9, max_generations=10,
              backend="ref")
    sw = c.pack_sweep(probs, "ga-nfd", seeds=[5, 6], **kw)
    assert sw.n_groups == 1
    for r, prob, s in zip(sw.results, probs, [5, 6]):
        ref = c.pack(prob, "ga-nfd", seed=s, **kw)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace]


def test_sweep_serial_fallback_lanes():
    """sa-nfd (scalar-only) and heuristics run the serial lane and still
    match pack() exactly."""
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    for algo, kw in (
        ("sa-nfd", dict(max_seconds=1e9, patience=10**9, max_iterations=60,
                        backend="python")),
        ("nfd", {}),
        ("ffd", {}),
    ):
        sw = c.pack_sweep(probs, algo, seeds=[1, 2], **kw)
        for r, prob, s in zip(sw.results, probs, [1, 2]):
            ref = c.pack(prob, algo, seed=s, **kw)
            assert r.cost == ref.cost, (algo, prob.name)
            assert r.solution.bins == ref.solution.bins


# ----------------------------------------------------------- dedup + caching
def test_sweep_dedup_and_cache():
    prob = c.get_problem("CNV-W1A1")
    clone = PackingProblem(c.get_buffers("CNV-W1A1"), name="renamed-dup")
    other = c.get_problem("CNV-W2A2")
    cache: dict = {}
    sw = c.pack_sweep([prob, clone, other], "sa-s", seed=0, n_chains=3,
                      cache=cache, **_SA_KW)
    # the renamed duplicate is served by fingerprint dedup, not solved
    assert sw.n_solved == 2 and sw.cache_hits == 1
    assert sw.results[0] is sw.results[1]
    assert len(cache) == 2
    # params counters split the dedup/cache sources (PR 8): the duplicate is
    # a dedup hit (same fingerprint in one fleet), not a cache hit
    assert sw.params["solved"] == 2
    assert sw.params["dedup_hits"] == 1
    assert sw.params["cache_hits"] == 0
    assert sw.params["n_shards"] == 1
    # a second sweep over a superset is served entirely from the cache
    sw2 = c.pack_sweep([prob, other, clone], "sa-s", seed=0, n_chains=3,
                       cache=cache, **_SA_KW)
    assert sw2.n_solved == 0 and sw2.cache_hits == 3
    assert sw2.results[0].cost == sw.results[0].cost
    # 2 unique tasks served from the cache, the clone collapsed by dedup
    assert sw2.params["solved"] == 0
    assert sw2.params["cache_hits"] == 2
    assert sw2.params["dedup_hits"] == 1
    assert (sw2.params["solved"] + sw2.params["cache_hits"]
            + sw2.params["dedup_hits"]) == sw2.size
    # different seed or budget = different task = fresh solve
    sw3 = c.pack_sweep([prob], "sa-s", seed=1, n_chains=3, cache=cache,
                       **_SA_KW)
    assert sw3.n_solved == 1


def test_sweep_seed_validation_and_empty():
    prob = c.get_problem("CNV-W1A1")
    with pytest.raises(ValueError):
        c.pack_sweep([], "sa-s")
    with pytest.raises(ValueError):
        c.pack_sweep([prob], "sa-s", seeds=[1, 2])


# ------------------------------------------------------------- sweep report
def test_sweep_report_and_pareto():
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    sw = c.pack_sweep(probs, "nfd", seed=0)
    assert sw.size == 2
    assert sw.candidates_per_sec > 0
    pareto = sw.pareto_indices()
    assert pareto  # the front is never empty
    # every non-front candidate is dominated by some front candidate
    cost, eff = sw.costs(), [r.efficiency for r in sw.results]
    for i in range(sw.size):
        if i not in pareto:
            assert any(
                cost[j] <= cost[i] and eff[j] >= eff[i] for j in pareto
            )
    text = sw.table()
    assert "CNV-W1A1" in text and "pareto" in text and "solve" in text
    assert sw.summary() in text


def test_sweep_equal_budget_costs_match_serial():
    """The ISSUE acceptance criterion's cost half: at equal iteration
    budgets the batched sweep's per-problem costs equal the serial loop's
    (they are the same trajectories)."""
    probs = [
        c.get_problem(name, device=dev)
        for name in ("CNV-W1A1", "CNV-W2A2")
        for dev in (None, "ZU7EV")
    ]
    sw = c.pack_sweep(probs, "sa-s", seed=0, n_chains=3, **_SA_KW)
    serial = [_standalone_sa(p, 0, n_chains=3) for p in probs]
    assert [r.cost for r in sw.results] == [r.cost for r in serial]


def test_sweep_frozen_problem_not_revived_by_exchange():
    """Regression: the fleet exchange tick must skip frozen problems.

    With ``patience < exchange_every`` windows a problem can freeze between
    exchange ticks while a fleet-mate stays live; the exchange used to
    reset the frozen problem's worst chain (``stale = 0``), reviving it to
    draw RNG its standalone run never draws.  Iterations (and thus
    trajectories) must match the standalone runs exactly.
    """
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("RN101-W1A2")]
    kw = dict(max_seconds=1e9, patience=60, max_iterations=20_000,
              exchange_every=70, backend="python")
    sw = c.pack_sweep(probs, "sa-s", seeds=[0, 1], n_chains=3, **kw)
    for r, prob, s in zip(sw.results, probs, [0, 1]):
        ref = c.pack(prob, "sa-s", seed=s, n_chains=3, **kw)
        assert r.iterations == ref.iterations, prob.name
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins, prob.name
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace]


# ------------------------------------------------- block engine direct access
def test_anneal_block_warm_starts():
    """The fleet engine accepts per-problem warm-start chain lists."""
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    packer = SimulatedAnnealingPacker(
        perturbation="swap", backend="python", n_chains=3,
        max_seconds=1e9, patience=10**9, max_iterations=150,
    )
    packer._hetero = False
    rngs = [np.random.default_rng(0), np.random.default_rng(1)]
    first = packer._anneal_block(probs, rngs, [[], []], "python")
    inits = [blk.chains for blk in first]
    rngs = [np.random.default_rng(2), np.random.default_rng(3)]
    second = packer._anneal_block(probs, rngs, inits, "python")
    for blk, prev in zip(second, first):
        blk.best.validate()
        # the run's best never loses to the warm chains it started from
        assert blk.best_cost <= min(s.cost() for s in prev.chains)
