"""Incremental delta-cost engine: cache consistency, backend parity,
batched-kernel/scalar agreement, warm starts, and the island portfolio."""
import numpy as np
import pytest

import repro.core as c
from repro.core.ga import GeneticPacker, buffer_swap
from repro.core.nfd import nfd_from_scratch, nfd_repack
from repro.core.problem import Buffer, PackingProblem, Solution
from repro.core.sa import SimulatedAnnealingPacker


def random_problem(rng, n=None, max_items=None):
    n = n or int(rng.integers(2, 60))
    bufs = [
        Buffer(
            width=int(rng.integers(1, 80)),
            depth=int(rng.integers(1, 40_000)),
            layer=int(rng.integers(0, 6)),
        )
        for _ in range(n)
    ]
    return PackingProblem(bufs, max_items=max_items or int(rng.integers(1, 6)))


# ------------------------------------------------------- Solution caching
def test_solution_from_generator_of_generators():
    """Regression: the seed consumed generator bins in the filter clause and
    then materialized them as empty."""
    prob = c.get_problem("CNV-W1A1")
    ref = prob.singleton_solution()
    sol = Solution(prob, (iter(b) for b in ref.bins))
    assert sol.bins == ref.bins
    assert sol.cost() == ref.cost()


def test_empty_bins_filtered_but_contents_kept():
    prob = random_problem(np.random.default_rng(0), n=6, max_items=6)
    sol = Solution(prob, [[0, 1], [], [2, 3], [], [4, 5]])
    assert sol.bins == [[0, 1], [2, 3], [4, 5]]
    sol.validate()


@pytest.mark.parametrize("seed", range(8))
def test_incremental_cost_matches_full_after_mutations(seed):
    """The incremental geometry cache must agree with a from-scratch rescan
    after arbitrary chains of both mutation operators."""
    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    sol = nfd_from_scratch(prob, rng, p_adm_h=0.2)
    for step in range(12):
        if step % 2 == 0:
            sol = nfd_repack(sol, rng, threshold=0.9, extra_frac=0.1, p_adm_h=0.3)
        else:
            sol = buffer_swap(sol, rng, n_moves=3)
        sol.validate()
        assert sol.cost() == sol.cost_full()
        np.testing.assert_array_equal(
            sol.bin_efficiencies(), sol.bin_efficiencies_full()
        )
        assert sol.distinct_layers_per_bin() == pytest.approx(
            sol.distinct_layers_per_bin_full()
        )


def test_touch_protocol_on_manual_edit():
    prob = c.get_problem("CNV-W1A1")
    sol = prob.singleton_solution()
    assert sol.cost() == sol.cost_full()  # populate the cache first
    item = sol.bins[1].pop()
    sol.bins[0].append(item)
    sol.touch(0, 1)
    sol.drop_empty()
    sol.validate()
    assert sol.cost() == sol.cost_full()


def test_copy_is_independent():
    rng = np.random.default_rng(3)
    prob = random_problem(rng, n=20, max_items=4)
    a = nfd_from_scratch(prob, rng)
    b = a.copy()
    b = buffer_swap(b, rng, n_moves=4)
    assert a.cost() == a.cost_full()
    assert b.cost() == b.cost_full()


# -------------------------------------------------- kernel/scalar parity
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("seed", range(6))
def test_population_costs_matches_solution_cost(backend, seed):
    """Batched population totals == per-individual Solution.cost(), on
    randomized problems with empty-bin padding and non-lane-multiple bin
    counts (the kernel pads NB internally to a lane multiple)."""
    import jax.numpy as jnp

    from repro.kernels.binpack_fitness.ops import population_costs

    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    pop = [nfd_from_scratch(prob, rng, p_adm_h=0.3) for _ in range(5)]
    nb_pad = prob.n + int(rng.integers(0, 9))  # deliberately not 128-aligned
    W = np.zeros((len(pop), nb_pad), dtype=np.int32)
    H = np.zeros((len(pop), nb_pad), dtype=np.int32)
    for i, s in enumerate(pop):
        s.fill_geometry(W[i], H[i])
    totals = np.asarray(
        population_costs(jnp.asarray(W), jnp.asarray(H), backend=backend)
    )
    for i, s in enumerate(pop):
        assert int(totals[i]) == s.cost() == s.cost_full()


def test_population_costs_auto_backend():
    import jax.numpy as jnp

    from repro.kernels.binpack_fitness.ops import population_costs

    W = np.array([[36, 0, 7]], dtype=np.int32)
    H = np.array([[1024, 0, 5000]], dtype=np.int32)
    auto = population_costs(jnp.asarray(W), jnp.asarray(H), backend="auto")
    ref = population_costs(jnp.asarray(W), jnp.asarray(H), backend="ref")
    assert int(auto[0]) == int(ref[0])


# ------------------------------------------------------- GA backend parity
@pytest.mark.parametrize("name", ["CNV-W1A1", "CNV-W2A2"])
def test_ga_backends_bit_identical(name):
    """Fixed seed + fixed generations => identical best solution, identical
    cost trace across every evaluation backend (the acceptance criterion)."""
    prob = c.get_problem(name)
    results = {}
    for backend in ("legacy", "python", "ref", "pallas"):
        packer = GeneticPacker(
            backend=backend,
            seed=7,
            max_generations=25,
            max_seconds=1e9,
            patience=10**9,
        )
        results[backend] = packer.pack(prob)
    ref = results["legacy"]
    for backend, r in results.items():
        assert r.cost == ref.cost, backend
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace], backend
        assert r.solution.bins == ref.solution.bins, backend
        r.solution.validate()
        assert r.solution.cost() == r.solution.cost_full() == r.cost


def test_ga_swap_mutation_backends_identical():
    prob = c.get_problem("CNV-W1A1")
    results = [
        GeneticPacker(
            mutation="swap",
            backend=backend,
            seed=11,
            max_generations=20,
            max_seconds=1e9,
            patience=10**9,
        ).pack(prob)
        for backend in ("legacy", "python", "ref")
    ]
    assert len({r.cost for r in results}) == 1
    assert results[0].solution.bins == results[1].solution.bins


def test_sa_incremental_consistency():
    prob = c.get_problem("CNV-W2A2")
    r = SimulatedAnnealingPacker(seed=2, max_seconds=1.5).pack(prob)
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost


# ------------------------------------------------------- SA backend parity
def _sa(backend, n_chains=1, **kw):
    kw.setdefault("seed", 5)
    kw.setdefault("max_iterations", 400)
    return SimulatedAnnealingPacker(
        perturbation="swap", backend=backend, n_chains=n_chains,
        max_seconds=1e9, patience=10**9, **kw,
    )


def test_sa_swap_backends_bit_identical():
    """Fixed seed, single chain => the delta engine must reproduce the
    legacy scalar trajectory bit-for-bit on every backend (the acceptance
    criterion), including the iteration count and the final bins."""
    prob = c.get_problem("CNV-W1A1")
    results = {
        backend: _sa(backend).pack(prob)
        for backend in ("legacy", "python", "ref", "pallas", "auto")
    }
    ref = results["legacy"]
    assert ref.iterations == 400
    for backend, r in results.items():
        assert r.cost == ref.cost, backend
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace], backend
        assert r.solution.bins == ref.solution.bins, backend
        assert r.iterations == ref.iterations, backend
        r.solution.validate()
        assert r.solution.cost() == r.solution.cost_full() == r.cost


def test_sa_single_chain_long_trajectory_parity():
    """Longer cheap (no-jax) run: the conditional Metropolis draw keeps the
    python engine on the legacy RNG stream through thousands of steps."""
    prob = c.get_problem("CNV-W2A2")
    a = _sa("legacy", seed=11, max_iterations=3000).pack(prob)
    b = _sa("python", seed=11, max_iterations=3000).pack(prob)
    assert a.cost == b.cost
    assert a.solution.bins == b.solution.bins
    assert [cc for _, cc in a.trace] == [cc for _, cc in b.trace]


def test_sa_multi_chain_backends_identical():
    """The vectorized multi-chain engine is deterministic per seed and
    backend-independent (deltas are exact integers in every backend)."""
    prob = c.get_problem("CNV-W2A2")
    results = [
        _sa(backend, n_chains=5, seed=3, max_iterations=200,
            exchange_every=50).pack(prob)
        for backend in ("python", "ref", "pallas")
    ]
    first = results[0]
    assert first.iterations == 5 * 200
    for r in results[1:]:
        assert r.cost == first.cost
        assert r.solution.bins == first.solution.bins
        assert [cc for _, cc in r.trace] == [cc for _, cc in first.trace]
    first.solution.validate()
    # the decoded best independently re-derives the incremental cost
    assert first.solution.cost() == first.solution.cost_full() == first.cost


def test_sa_multi_chain_intra_layer():
    prob = c.get_problem("CNV-W1A1")
    r = _sa("python", n_chains=4, seed=1, max_iterations=300,
            intra_layer=True).pack(prob)
    r.solution.validate(intra_layer=True)


def test_metropolis_acceptance_statistics():
    """Empirical uphill-acceptance frequency matches exp(-d/T)."""
    import math

    from repro.kernels.binpack_sa_step.ops import metropolis_mask

    rng = np.random.default_rng(0)
    n = 40_000
    d = np.full(n, 3.0)
    t = np.full(n, 6.0)
    acc = metropolis_mask(d, t, rng.random(n))
    p = math.exp(-0.5)
    sigma = math.sqrt(p * (1 - p) / n)
    assert abs(acc.mean() - p) < 4 * sigma
    # downhill always accepted; frozen (T=0) uphill never
    assert metropolis_mask([-1.0], [0.0], [0.999]).all()
    assert not metropolis_mask([1.0], [0.0], [0.0]).any()


def test_sa_uphill_acceptance_follows_temperature():
    """Engine-level Metropolis sanity: a hot constant ladder accepts almost
    every uphill move, a frozen one almost none (rc=0 pins T = T0)."""
    prob = c.get_problem("CNV-W1A1")
    rates = {}
    for label, t0 in (("hot", 1e9), ("cold", 1e-9)):
        r = _sa("python", n_chains=4, seed=0, max_iterations=300,
                t0=t0, rc=0.0).pack(prob)
        p = r.params
        assert p["uphill_proposed"] > 50
        rates[label] = p["uphill_accepted"] / p["uphill_proposed"]
    assert rates["hot"] > 0.95
    assert rates["cold"] < 0.05


# ------------------------------------------------------------ warm starts
def test_ga_warm_start_from_population():
    prob = c.get_problem("CNV-W1A1")
    first = GeneticPacker(seed=0, max_generations=10, backend="python",
                          max_seconds=1e9, patience=10**9)
    r1 = first.pack(prob)
    assert first.last_population_ is not None
    second = GeneticPacker(seed=1, max_generations=10, backend="python",
                           max_seconds=1e9, patience=10**9)
    r2 = second.pack(prob, init_pop=first.last_population_)
    r2.solution.validate()
    assert r2.cost <= max(s.cost() for s in first.last_population_)


def test_sa_warm_start_from_solution():
    prob = c.get_problem("CNV-W1A1")
    sa = SimulatedAnnealingPacker(seed=0, max_seconds=0.5)
    r1 = sa.pack(prob)
    assert sa.last_solution_ is not None
    r2 = sa.pack(prob, init=r1.solution)
    r2.solution.validate()
    assert r2.cost <= r1.cost


def test_sa_multi_chain_warm_start_from_chains():
    prob = c.get_problem("CNV-W1A1")
    sa = _sa("python", n_chains=3, seed=0, max_iterations=200)
    r1 = sa.pack(prob)
    assert sa.last_chains_ is not None and len(sa.last_chains_) == 3
    for s in sa.last_chains_:
        s.validate()
    r2 = _sa("python", n_chains=3, seed=1, max_iterations=200).pack(
        prob, init=sa.last_chains_
    )
    r2.solution.validate()
    assert r2.cost <= min(s.cost() for s in sa.last_chains_)


# -------------------------------------------------------------- portfolio
def test_portfolio_basic():
    # iteration/generation budgets (max_seconds is a safety cap only):
    # wall-budgeted portfolio runs are machine-dependent and leak the
    # TruncationWarning that pytest.ini promotes to an error
    prob = c.get_problem("CNV-W2A2")
    r = c.pack_portfolio(
        prob, n_islands=3, seed=0, max_seconds=60.0, backend="python",
        max_iterations=1280, max_generations=24,
    )
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost
    assert r.cost <= prob.baseline_cost()
    assert prob.lower_bound() <= r.cost
    costs = [cc for _, cc in r.trace]
    assert all(a >= b for a, b in zip(costs, costs[1:]))
    assert r.params["barriers"] >= 1
    assert len(r.params["islands"]) == 3
    assert r.algorithm.startswith("portfolio[")


def test_portfolio_via_pack_and_single_island():
    prob = c.get_problem("CNV-W1A1")
    r = c.pack(prob, "portfolio", seed=0, max_seconds=60.0, n_islands=1,
               backend="python", max_generations=40)
    r.solution.validate()
    assert r.cost <= prob.baseline_cost()


def test_portfolio_batched_sa_island():
    """One batched sa-s island (sa_chains chains) rides in the portfolio,
    warm-restarts across rounds, and receives migrants like any island."""
    prob = c.get_problem("CNV-W1A1")
    r = c.pack_portfolio(
        prob,
        algorithms=("ga-nfd", "sa-s"),
        n_islands=2,
        seed=0,
        max_seconds=60.0,
        backend="python",
        sa_chains=3,
        max_iterations=1280,
        max_generations=24,
    )
    r.solution.validate()
    assert r.cost <= prob.baseline_cost()
    sa_islands = [i for i in r.params["islands"] if i["algorithm"] == "sa-s"]
    assert sa_islands


def test_portfolio_explicit_island_specs():
    prob = c.get_problem("CNV-W1A1")
    islands = [
        c.IslandSpec("ga-nfd", seed=0),
        c.IslandSpec("sa-nfd", seed=5, hyper={"sa_t0": 10.0}),
    ]
    r = c.pack_portfolio(prob, islands=islands, max_seconds=60.0,
                         backend="python", max_iterations=2000,
                         max_generations=30)
    r.solution.validate()
    assert [i["algorithm"] for i in r.params["islands"]] == ["ga-nfd", "sa-nfd"]


def test_portfolio_rejects_empty():
    prob = c.get_problem("CNV-W1A1")
    with pytest.raises(ValueError):
        c.pack_portfolio(prob, n_islands=0)
    with pytest.raises(ValueError):
        c.pack_portfolio(prob, islands=[])


def test_make_packer_rejects_heuristics():
    with pytest.raises(ValueError):
        c.make_packer("ffd")
    with pytest.raises(ValueError):
        GeneticPacker(backend="cuda")
    with pytest.raises(ValueError):
        SimulatedAnnealingPacker(backend="cuda")
    with pytest.raises(ValueError):
        SimulatedAnnealingPacker(n_chains=0)
