"""Incremental delta-cost engine: cache consistency, backend parity,
batched-kernel/scalar agreement, warm starts, and the island portfolio."""
import numpy as np
import pytest

import repro.core as c
from repro.core.ga import GeneticPacker, buffer_swap
from repro.core.nfd import nfd_from_scratch, nfd_repack
from repro.core.problem import Buffer, PackingProblem, Solution
from repro.core.sa import SimulatedAnnealingPacker


def random_problem(rng, n=None, max_items=None):
    n = n or int(rng.integers(2, 60))
    bufs = [
        Buffer(
            width=int(rng.integers(1, 80)),
            depth=int(rng.integers(1, 40_000)),
            layer=int(rng.integers(0, 6)),
        )
        for _ in range(n)
    ]
    return PackingProblem(bufs, max_items=max_items or int(rng.integers(1, 6)))


# ------------------------------------------------------- Solution caching
def test_solution_from_generator_of_generators():
    """Regression: the seed consumed generator bins in the filter clause and
    then materialized them as empty."""
    prob = c.get_problem("CNV-W1A1")
    ref = prob.singleton_solution()
    sol = Solution(prob, (iter(b) for b in ref.bins))
    assert sol.bins == ref.bins
    assert sol.cost() == ref.cost()


def test_empty_bins_filtered_but_contents_kept():
    prob = random_problem(np.random.default_rng(0), n=6, max_items=6)
    sol = Solution(prob, [[0, 1], [], [2, 3], [], [4, 5]])
    assert sol.bins == [[0, 1], [2, 3], [4, 5]]
    sol.validate()


@pytest.mark.parametrize("seed", range(8))
def test_incremental_cost_matches_full_after_mutations(seed):
    """The incremental geometry cache must agree with a from-scratch rescan
    after arbitrary chains of both mutation operators."""
    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    sol = nfd_from_scratch(prob, rng, p_adm_h=0.2)
    for step in range(12):
        if step % 2 == 0:
            sol = nfd_repack(sol, rng, threshold=0.9, extra_frac=0.1, p_adm_h=0.3)
        else:
            sol = buffer_swap(sol, rng, n_moves=3)
        sol.validate()
        assert sol.cost() == sol.cost_full()
        np.testing.assert_array_equal(
            sol.bin_efficiencies(), sol.bin_efficiencies_full()
        )
        assert sol.distinct_layers_per_bin() == pytest.approx(
            sol.distinct_layers_per_bin_full()
        )


def test_touch_protocol_on_manual_edit():
    prob = c.get_problem("CNV-W1A1")
    sol = prob.singleton_solution()
    assert sol.cost() == sol.cost_full()  # populate the cache first
    item = sol.bins[1].pop()
    sol.bins[0].append(item)
    sol.touch(0, 1)
    sol.drop_empty()
    sol.validate()
    assert sol.cost() == sol.cost_full()


def test_copy_is_independent():
    rng = np.random.default_rng(3)
    prob = random_problem(rng, n=20, max_items=4)
    a = nfd_from_scratch(prob, rng)
    b = a.copy()
    b = buffer_swap(b, rng, n_moves=4)
    assert a.cost() == a.cost_full()
    assert b.cost() == b.cost_full()


# -------------------------------------------------- kernel/scalar parity
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("seed", range(6))
def test_population_costs_matches_solution_cost(backend, seed):
    """Batched population totals == per-individual Solution.cost(), on
    randomized problems with empty-bin padding and non-lane-multiple bin
    counts (the kernel pads NB internally to a lane multiple)."""
    import jax.numpy as jnp

    from repro.kernels.binpack_fitness.ops import population_costs

    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    pop = [nfd_from_scratch(prob, rng, p_adm_h=0.3) for _ in range(5)]
    nb_pad = prob.n + int(rng.integers(0, 9))  # deliberately not 128-aligned
    W = np.zeros((len(pop), nb_pad), dtype=np.int32)
    H = np.zeros((len(pop), nb_pad), dtype=np.int32)
    for i, s in enumerate(pop):
        s.fill_geometry(W[i], H[i])
    totals = np.asarray(
        population_costs(jnp.asarray(W), jnp.asarray(H), backend=backend)
    )
    for i, s in enumerate(pop):
        assert int(totals[i]) == s.cost() == s.cost_full()


def test_population_costs_auto_backend():
    import jax.numpy as jnp

    from repro.kernels.binpack_fitness.ops import population_costs

    W = np.array([[36, 0, 7]], dtype=np.int32)
    H = np.array([[1024, 0, 5000]], dtype=np.int32)
    auto = population_costs(jnp.asarray(W), jnp.asarray(H), backend="auto")
    ref = population_costs(jnp.asarray(W), jnp.asarray(H), backend="ref")
    assert int(auto[0]) == int(ref[0])


# ------------------------------------------------------- GA backend parity
@pytest.mark.parametrize("name", ["CNV-W1A1", "CNV-W2A2"])
def test_ga_backends_bit_identical(name):
    """Fixed seed + fixed generations => identical best solution, identical
    cost trace across every evaluation backend (the acceptance criterion)."""
    prob = c.get_problem(name)
    results = {}
    for backend in ("legacy", "python", "ref", "pallas"):
        packer = GeneticPacker(
            backend=backend,
            seed=7,
            max_generations=25,
            max_seconds=1e9,
            patience=10**9,
        )
        results[backend] = packer.pack(prob)
    ref = results["legacy"]
    for backend, r in results.items():
        assert r.cost == ref.cost, backend
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace], backend
        assert r.solution.bins == ref.solution.bins, backend
        r.solution.validate()
        assert r.solution.cost() == r.solution.cost_full() == r.cost


def test_ga_swap_mutation_backends_identical():
    prob = c.get_problem("CNV-W1A1")
    results = [
        GeneticPacker(
            mutation="swap",
            backend=backend,
            seed=11,
            max_generations=20,
            max_seconds=1e9,
            patience=10**9,
        ).pack(prob)
        for backend in ("legacy", "python", "ref")
    ]
    assert len({r.cost for r in results}) == 1
    assert results[0].solution.bins == results[1].solution.bins


def test_sa_incremental_consistency():
    prob = c.get_problem("CNV-W2A2")
    r = SimulatedAnnealingPacker(seed=2, max_seconds=1.5).pack(prob)
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost


# ------------------------------------------------------------ warm starts
def test_ga_warm_start_from_population():
    prob = c.get_problem("CNV-W1A1")
    first = GeneticPacker(seed=0, max_generations=10, backend="python",
                          max_seconds=1e9, patience=10**9)
    r1 = first.pack(prob)
    assert first.last_population_ is not None
    second = GeneticPacker(seed=1, max_generations=10, backend="python",
                           max_seconds=1e9, patience=10**9)
    r2 = second.pack(prob, init_pop=first.last_population_)
    r2.solution.validate()
    assert r2.cost <= max(s.cost() for s in first.last_population_)


def test_sa_warm_start_from_solution():
    prob = c.get_problem("CNV-W1A1")
    sa = SimulatedAnnealingPacker(seed=0, max_seconds=0.5)
    r1 = sa.pack(prob)
    assert sa.last_solution_ is not None
    r2 = sa.pack(prob, init=r1.solution)
    r2.solution.validate()
    assert r2.cost <= r1.cost


# -------------------------------------------------------------- portfolio
def test_portfolio_basic():
    prob = c.get_problem("CNV-W2A2")
    r = c.pack_portfolio(
        prob, n_islands=3, seed=0, max_seconds=2.0, backend="python"
    )
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost
    assert r.cost <= prob.baseline_cost()
    assert prob.lower_bound() <= r.cost
    costs = [cc for _, cc in r.trace]
    assert all(a >= b for a, b in zip(costs, costs[1:]))
    assert r.params["rounds"] >= 1
    assert len(r.params["islands"]) == 3
    assert r.algorithm.startswith("portfolio[")


def test_portfolio_via_pack_and_single_island():
    prob = c.get_problem("CNV-W1A1")
    r = c.pack(prob, "portfolio", seed=0, max_seconds=1.0, n_islands=1,
               backend="python")
    r.solution.validate()
    assert r.cost <= prob.baseline_cost()


def test_portfolio_explicit_island_specs():
    prob = c.get_problem("CNV-W1A1")
    islands = [
        c.IslandSpec("ga-nfd", seed=0),
        c.IslandSpec("sa-nfd", seed=5, hyper={"sa_t0": 10.0}),
    ]
    r = c.pack_portfolio(prob, islands=islands, max_seconds=1.0,
                         backend="python")
    r.solution.validate()
    assert [i["algorithm"] for i in r.params["islands"]] == ["ga-nfd", "sa-nfd"]


def test_portfolio_rejects_empty():
    prob = c.get_problem("CNV-W1A1")
    with pytest.raises(ValueError):
        c.pack_portfolio(prob, n_islands=0)
    with pytest.raises(ValueError):
        c.pack_portfolio(prob, islands=[])


def test_make_packer_rejects_heuristics():
    with pytest.raises(ValueError):
        c.make_packer("ffd")
    with pytest.raises(ValueError):
        GeneticPacker(backend="cuda")
