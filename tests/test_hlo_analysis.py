"""The trip-count-aware HLO cost model (backbone of the roofline)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def test_scan_flops_match_unrolled():
    n = 128
    w = jnp.ones((8, n, n))

    def scanned(x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ w[i])
        return h

    x = jnp.ones((n, n))
    cs = analyze_hlo(jax.jit(scanned).lower(x).compile().as_text())
    cu = analyze_hlo(jax.jit(unrolled).lower(x).compile().as_text())
    expect = 2 * n**3 * 8
    assert abs(cs.flops - expect) / expect < 0.05
    assert abs(cu.flops - expect) / expect < 0.05
    assert cs.unknown_trip == 0


def test_dot_flops_exact():
    a = jnp.ones((64, 256))
    b = jnp.ones((256, 32))
    c = analyze_hlo(jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text())
    assert c.flops >= 2 * 64 * 256 * 32
    assert c.flops < 2 * 64 * 256 * 32 * 1.1


def test_artifact_bf16_halving():
    """CPU widens bf16 dots to f32; the model must charge bf16 bytes."""
    a = jnp.ones((256, 512), jnp.bfloat16)
    b = jnp.ones((512, 256), jnp.bfloat16)
    cost = analyze_hlo(jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text())
    # traffic should be ~(read a + read b + write out) at bf16 = 3*256*512*2
    expect = 3 * 256 * 512 * 2
    assert cost.bytes <= expect * 1.5, cost.bytes


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, 0.0)  # exactly one second of compute
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == 1.0
    t = roofline_terms(0.0, 819e9, 50e9)
    assert t["dominant"] in ("memory_s", "collective_s")
    assert t["memory_s"] == 1.0 and t["collective_s"] == 1.0
