"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.problem import BRAM18_MODES
from repro.kernels.binpack_fitness.kernel import binpack_fitness_pallas
from repro.kernels.binpack_fitness.ops import population_costs
from repro.kernels.binpack_fitness.ref import binpack_fitness_ref
from repro.kernels.packed_gather.kernel import packed_gather_matvec
from repro.kernels.packed_gather.ops import bank_matvec, split_outputs
from repro.kernels.packed_gather.ref import packed_gather_ref


@pytest.mark.parametrize("p,nb", [(1, 1), (4, 37), (50, 300), (8, 128), (75, 1000)])
def test_binpack_fitness_matches_ref(p, nb, rng):
    w = rng.integers(0, 80, (p, nb)).astype(np.int32)
    w[rng.random((p, nb)) < 0.25] = 0
    h = rng.integers(1, 70_000, (p, nb)).astype(np.int32)
    a = binpack_fitness_pallas(jnp.asarray(w), jnp.asarray(h), BRAM18_MODES, True)
    b = binpack_fitness_ref(jnp.asarray(w), jnp.asarray(h), BRAM18_MODES)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_binpack_fitness_against_core_solution(rng):
    """Kernel totals must equal the core Solution.cost() bookkeeping."""
    import repro.core as c

    prob = c.get_problem("CNV-W2A2")
    sol = c.nfd_from_scratch(prob, np.random.default_rng(0))
    nb = len(sol.bins)
    w = np.zeros((1, nb), np.int32)
    h = np.zeros((1, nb), np.int32)
    for i, b in enumerate(sol.bins):
        bw, bh, _ = prob.bin_stats(b)
        w[0, i], h[0, i] = bw, bh
    total = population_costs(jnp.asarray(w), jnp.asarray(h))
    assert int(total[0]) == sol.cost()


@pytest.mark.parametrize("seed", range(20))
def test_packed_gather_property(seed):
    # seeded random sweep (no hypothesis dependency for the tier-1 suite)
    rng = np.random.default_rng(seed)
    r = 8 * int(rng.integers(1, 7))
    c = 128 * int(rng.integers(1, 5))
    n = int(rng.integers(1, 7))
    bank = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, n, r), jnp.int32)
    a = packed_gather_matvec(bank, x, seg, interpret=True)
    b = packed_gather_ref(bank, x, seg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_packed_gather_split_outputs(rng):
    r, c, n = 24, 128, 3
    bank = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    seg = jnp.asarray(np.repeat(np.arange(n), r // n), jnp.int32)
    y = bank_matvec(bank, x, seg, backend="ref")
    parts = split_outputs(y, seg, n)
    assert sum(p.shape[0] for p in parts) == r
