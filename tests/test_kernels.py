"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.problem import BRAM18_MODES
from repro.kernels.binpack_fitness.kernel import binpack_fitness_pallas
from repro.kernels.binpack_fitness.ops import population_costs
from repro.kernels.binpack_fitness.ref import binpack_fitness_ref
from repro.kernels.binpack_sa_step.ops import metropolis_mask, sa_step_deltas
from repro.kernels.packed_gather.kernel import packed_gather_matvec
from repro.kernels.packed_gather.ops import bank_matvec, split_outputs
from repro.kernels.packed_gather.ref import packed_gather_ref


@pytest.mark.parametrize("p,nb", [(1, 1), (4, 37), (50, 300), (8, 128), (75, 1000)])
def test_binpack_fitness_matches_ref(p, nb, rng):
    w = rng.integers(0, 80, (p, nb)).astype(np.int32)
    w[rng.random((p, nb)) < 0.25] = 0
    h = rng.integers(1, 70_000, (p, nb)).astype(np.int32)
    a = binpack_fitness_pallas(jnp.asarray(w), jnp.asarray(h), BRAM18_MODES, True)
    b = binpack_fitness_ref(jnp.asarray(w), jnp.asarray(h), BRAM18_MODES)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_binpack_fitness_against_core_solution(rng):
    """Kernel totals must equal the core Solution.cost() bookkeeping."""
    import repro.core as c

    prob = c.get_problem("CNV-W2A2")
    sol = c.nfd_from_scratch(prob, np.random.default_rng(0))
    nb = len(sol.bins)
    w = np.zeros((1, nb), np.int32)
    h = np.zeros((1, nb), np.int32)
    for i, b in enumerate(sol.bins):
        bw, bh, _ = prob.bin_stats(b)
        w[0, i], h[0, i] = bw, bh
    total = population_costs(jnp.asarray(w), jnp.asarray(h))
    assert int(total[0]) == sol.cost()


@pytest.mark.parametrize("c,t", [(1, 1), (3, 4), (16, 8), (9, 130), (40, 2)])
def test_sa_step_deltas_backends_agree(c, t, rng):
    """python/ref/pallas SA-step deltas are identical and equal the direct
    per-bin cost difference, with zero-padded (empty) slots contributing 0."""
    ow = rng.integers(0, 80, (c, t)).astype(np.int32)
    ow[rng.random((c, t)) < 0.3] = 0
    oh = np.where(ow > 0, rng.integers(1, 70_000, (c, t)), 0).astype(np.int32)
    nw = rng.integers(0, 80, (c, t)).astype(np.int32)
    nw[rng.random((c, t)) < 0.3] = 0
    nh = np.where(nw > 0, rng.integers(1, 70_000, (c, t)), 0).astype(np.int32)
    py = sa_step_deltas(ow, oh, nw, nh, backend="python")
    rf = sa_step_deltas(ow, oh, nw, nh, backend="ref")
    pa = sa_step_deltas(ow, oh, nw, nh, backend="pallas")
    assert np.array_equal(py, rf)
    assert np.array_equal(py, pa)
    direct = np.asarray(
        binpack_fitness_ref(jnp.asarray(nw), jnp.asarray(nh), BRAM18_MODES)
    ).sum(1) - np.asarray(
        binpack_fitness_ref(jnp.asarray(ow), jnp.asarray(oh), BRAM18_MODES)
    ).sum(1)
    assert np.array_equal(py, direct)


def _random_kind_tables(rng):
    tables = []
    for _ in range(int(rng.integers(1, 4))):
        modes = tuple(
            (int(rng.integers(1, 96)), int(rng.integers(1, 40_000)))
            for _ in range(int(rng.integers(1, 6)))
        )
        tables.append((int(rng.integers(1, 32)), modes))
    return tuple(tables)


@pytest.mark.parametrize("seed", range(12))
def test_random_mode_sets_backends_agree(seed):
    """Seeded random-RAM-mode-set sweep (no hypothesis dependency): the
    numpy, jnp-ref, and Pallas per-kind cost evaluators must all equal the
    scalar min-over-modes formulation for arbitrary mode tables/weights."""
    from repro.kernels.binpack_fitness.kernel import binpack_fitness_kinds_pallas
    from repro.kernels.binpack_fitness.ref import binpack_fitness_kinds_ref
    from repro.kernels.binpack_sa_step.ops import _bin_costs_kinds_numpy

    rng = np.random.default_rng(seed)
    kind_tables = _random_kind_tables(rng)
    p, nb = int(rng.integers(1, 6)), int(rng.integers(1, 150))
    w = rng.integers(0, 100, (p, nb)).astype(np.int32)
    h = np.where(w > 0, rng.integers(1, 60_000, (p, nb)), 0).astype(np.int32)
    k = rng.integers(0, len(kind_tables), (p, nb)).astype(np.int32)
    legacy = np.zeros((p, nb), dtype=np.int64)
    for i in range(p):
        for j in range(nb):
            if w[i, j] > 0:
                weight, modes = kind_tables[int(k[i, j])]
                legacy[i, j] = weight * min(
                    -(-int(w[i, j]) // mw) * -(-int(h[i, j]) // md)
                    for mw, md in modes
                )
    python = _bin_costs_kinds_numpy(w, h, k, kind_tables)
    ref = np.asarray(
        binpack_fitness_kinds_ref(
            jnp.asarray(w), jnp.asarray(h), jnp.asarray(k), kind_tables
        )
    )
    pallas = np.asarray(
        binpack_fitness_kinds_pallas(
            jnp.asarray(w), jnp.asarray(h), jnp.asarray(k), kind_tables, True
        )
    )
    np.testing.assert_array_equal(python, legacy)
    np.testing.assert_array_equal(ref, legacy)
    np.testing.assert_array_equal(pallas, legacy)


@pytest.mark.parametrize("c,t", [(1, 1), (3, 4), (9, 130)])
def test_sa_step_deltas_kinds_backends_agree(c, t, rng):
    """Kind-lane SA deltas: python/ref/pallas agree and equal the direct
    per-kind cost difference (kind flips = same geometry, different kind)."""
    from repro.core.problem import BRAM18, URAM288
    from repro.kernels.binpack_fitness.ref import binpack_fitness_kinds_ref

    kind_tables = ((1, BRAM18.modes), (16, URAM288.modes))
    ow = rng.integers(0, 80, (c, t)).astype(np.int32)
    ow[rng.random((c, t)) < 0.3] = 0
    oh = np.where(ow > 0, rng.integers(1, 70_000, (c, t)), 0).astype(np.int32)
    ok = rng.integers(0, 2, (c, t)).astype(np.int32)
    nw = ow.copy()  # kind flips: geometry fixed, kinds flipped for half
    nh = oh.copy()
    nk = np.where(rng.random((c, t)) < 0.5, 1 - ok, ok).astype(np.int32)
    py = sa_step_deltas(ow, oh, nw, nh, backend="python",
                        old_k=ok, new_k=nk, kind_tables=kind_tables)
    rf = sa_step_deltas(ow, oh, nw, nh, backend="ref",
                        old_k=ok, new_k=nk, kind_tables=kind_tables)
    pa = sa_step_deltas(ow, oh, nw, nh, backend="pallas",
                        old_k=ok, new_k=nk, kind_tables=kind_tables)
    assert np.array_equal(py, rf)
    assert np.array_equal(py, pa)
    direct = np.asarray(
        binpack_fitness_kinds_ref(
            jnp.asarray(nw), jnp.asarray(nh), jnp.asarray(nk), kind_tables
        )
    ).sum(1) - np.asarray(
        binpack_fitness_kinds_ref(
            jnp.asarray(ow), jnp.asarray(oh), jnp.asarray(ok), kind_tables
        )
    ).sum(1)
    assert np.array_equal(py, direct)


def test_metropolis_mask_edge_cases():
    d = np.array([-5.0, 0.0, 2.0, 2.0, 1.0])
    t = np.array([0.0, 1.0, 1e12, 1e-12, 0.0])
    u = np.array([0.99, 0.5, 0.5, 0.5, 0.0])
    # downhill always; d=0 accepts (u < 1); hot accepts; frozen rejects
    np.testing.assert_array_equal(
        metropolis_mask(d, t, u), [True, True, True, False, False]
    )


@pytest.mark.parametrize("seed", range(20))
def test_packed_gather_property(seed):
    # seeded random sweep (no hypothesis dependency for the tier-1 suite)
    rng = np.random.default_rng(seed)
    r = 8 * int(rng.integers(1, 7))
    c = 128 * int(rng.integers(1, 5))
    n = int(rng.integers(1, 7))
    bank = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, n, r), jnp.int32)
    a = packed_gather_matvec(bank, x, seg, interpret=True)
    b = packed_gather_ref(bank, x, seg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_packed_gather_split_outputs(rng):
    r, c, n = 24, 128, 3
    bank = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    seg = jnp.asarray(np.repeat(np.arange(n), r // n), jnp.int32)
    y = bank_matvec(bank, x, seg, backend="ref")
    parts = split_outputs(y, seg, n)
    assert sum(p.shape[0] for p in parts) == r


@pytest.mark.parametrize("backend", ["python", "ref", "pallas"])
def test_problem_axis_matches_per_problem_slices(backend, rng):
    """The leading problem axis (NP, ., .) must equal stacking the 2-D calls
    per problem on every backend, for both kernels (DSE fleet contract)."""
    if backend != "python":
        npb, p, nb = 3, 4, 17
        w = rng.integers(0, 80, (npb, p, nb)).astype(np.int32)
        w[rng.random((npb, p, nb)) < 0.3] = 0
        h = np.where(w > 0, rng.integers(1, 60_000, (npb, p, nb)), 0).astype(np.int32)
        t3 = np.asarray(
            population_costs(jnp.asarray(w), jnp.asarray(h), backend=backend)
        )
        assert t3.shape == (npb, p)
        per = np.stack([
            np.asarray(population_costs(jnp.asarray(w[i]), jnp.asarray(h[i]),
                                        backend=backend))
            for i in range(npb)
        ])
        np.testing.assert_array_equal(t3, per)
    npb, cc, t = 3, 5, 4
    ow = rng.integers(0, 80, (npb, cc, t)).astype(np.int32)
    oh = np.where(ow > 0, rng.integers(1, 60_000, (npb, cc, t)), 0).astype(np.int32)
    nw = rng.integers(0, 80, (npb, cc, t)).astype(np.int32)
    nh = np.where(nw > 0, rng.integers(1, 60_000, (npb, cc, t)), 0).astype(np.int32)
    d3 = sa_step_deltas(ow, oh, nw, nh, backend=backend)
    assert d3.shape == (npb, cc)
    per = np.stack([
        sa_step_deltas(ow[i], oh[i], nw[i], nh[i], backend=backend)
        for i in range(npb)
    ])
    np.testing.assert_array_equal(d3, per)
    # kind lanes ride the problem axis too
    from repro.core.problem import BRAM18, URAM288

    kt = ((1, BRAM18.modes), (16, URAM288.modes))
    ok = rng.integers(0, 2, (npb, cc, t)).astype(np.int32)
    nk = rng.integers(0, 2, (npb, cc, t)).astype(np.int32)
    dk3 = sa_step_deltas(ow, oh, nw, nh, backend=backend,
                         old_k=ok, new_k=nk, kind_tables=kt)
    perk = np.stack([
        sa_step_deltas(ow[i], oh[i], nw[i], nh[i], backend=backend,
                       old_k=ok[i], new_k=nk[i], kind_tables=kt)
        for i in range(npb)
    ])
    np.testing.assert_array_equal(dk3, perk)


# ------------------------------------------------------ fused portfolio step
@pytest.mark.parametrize("backend", ["python", "ref", "pallas"])
def test_portfolio_step_matches_separate_dispatches(backend, rng):
    """The fused GA-fitness + SA-delta program is bit-identical to the two
    separate kernel dispatches it replaces, on every backend (the
    core.portfolio fused-barrier contract)."""
    from repro.kernels.binpack_portfolio_step.ops import portfolio_step

    a, p, nb, cc, t = 2, 5, 23, 7, 4
    w = rng.integers(0, 80, (a, p, nb)).astype(np.int32)
    w[rng.random((a, p, nb)) < 0.3] = 0
    h = np.where(w > 0, rng.integers(1, 60_000, (a, p, nb)), 0).astype(np.int32)
    ow = rng.integers(0, 80, (cc, t)).astype(np.int32)
    oh = np.where(ow > 0, rng.integers(1, 60_000, (cc, t)), 0).astype(np.int32)
    nw = rng.integers(0, 80, (cc, t)).astype(np.int32)
    nh = np.where(nw > 0, rng.integers(1, 60_000, (cc, t)), 0).astype(np.int32)
    totals, deltas = portfolio_step(w, h, ow, oh, nw, nh, backend=backend)
    assert totals.shape == (a, p) and totals.dtype == np.float64
    assert deltas.shape == (cc,) and deltas.dtype == np.int64
    np.testing.assert_array_equal(
        totals,
        np.asarray(population_costs(jnp.asarray(w), jnp.asarray(h),
                                    backend="ref")),
    )
    np.testing.assert_array_equal(
        deltas, sa_step_deltas(ow, oh, nw, nh, backend="python")
    )


@pytest.mark.parametrize("backend", ["python", "ref", "pallas"])
def test_portfolio_step_kinds_matches_separate_dispatches(backend, rng):
    from repro.core.problem import BRAM18, URAM288
    from repro.kernels.binpack_portfolio_step.ops import portfolio_step

    kt = ((1, BRAM18.modes), (16, URAM288.modes))
    a, p, nb, cc, t = 2, 4, 19, 6, 3
    w = rng.integers(0, 80, (a, p, nb)).astype(np.int32)
    w[rng.random((a, p, nb)) < 0.3] = 0
    h = np.where(w > 0, rng.integers(1, 60_000, (a, p, nb)), 0).astype(np.int32)
    km = rng.integers(0, 2, (a, p, nb)).astype(np.int32)
    ow = rng.integers(0, 80, (cc, t)).astype(np.int32)
    oh = np.where(ow > 0, rng.integers(1, 60_000, (cc, t)), 0).astype(np.int32)
    ok = rng.integers(0, 2, (cc, t)).astype(np.int32)
    nw = rng.integers(0, 80, (cc, t)).astype(np.int32)
    nh = np.where(nw > 0, rng.integers(1, 60_000, (cc, t)), 0).astype(np.int32)
    nk = rng.integers(0, 2, (cc, t)).astype(np.int32)
    totals, deltas = portfolio_step(
        w, h, ow, oh, nw, nh, backend=backend, kinds=km,
        old_k=ok, new_k=nk, kind_tables=kt,
    )
    np.testing.assert_array_equal(
        totals,
        np.asarray(population_costs(
            jnp.asarray(w), jnp.asarray(h), backend="ref",
            kinds=jnp.asarray(km), kind_tables=kt,
        )),
    )
    np.testing.assert_array_equal(
        deltas,
        sa_step_deltas(ow, oh, nw, nh, backend="python",
                       old_k=ok, new_k=nk, kind_tables=kt),
    )


def test_portfolio_step_rejects_partial_kind_lanes(rng):
    """kinds/old_k/new_k/kind_tables are all-or-none: a portfolio's islands
    share one problem, so half-hetero inputs are a caller bug."""
    from repro.kernels.binpack_portfolio_step.ops import portfolio_step

    z = np.zeros((2, 3), dtype=np.int32)
    with pytest.raises(ValueError, match="together"):
        portfolio_step(z, z, z, z, z, z, backend="python", old_k=z)
