"""TPU tile-grid adaptation: planner + packed store invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.memory import PackedParameterStore, plan_packing, tile_efficiency
from repro.memory.tiles import fold_2d, padded_bytes
from repro.models import model as M


def test_tile_padding_math():
    assert padded_bytes((1, 100), 4) == 8 * 128 * 4
    assert padded_bytes((8, 128), 4) == 8 * 128 * 4
    assert padded_bytes((9, 129), 4) == 16 * 256 * 4
    assert fold_2d((3, 4, 5)) == (12, 5)
    assert tile_efficiency((8, 128), 4) == 1.0
    assert tile_efficiency((1, 128), 4) == pytest.approx(1 / 8)


@pytest.mark.parametrize("arch", ["hymba-1.5b", "qwen2-0.5b", "whisper-medium"])
def test_store_roundtrip_exact(arch):
    cfg = configs.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plans = plan_packing(params, max_seconds=1.0, split_stacked=True)
    store = PackedParameterStore(params, plans)
    rebuilt = store.unpack()
    assert jax.tree.all(
        jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), params, rebuilt)
    )


def test_packing_never_increases_bytes():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    for plan in plan_packing(params, max_seconds=1.0, split_stacked=True).values():
        assert plan.padded_bytes_after <= plan.padded_bytes_before
        assert 0 < plan.efficiency_before() <= plan.efficiency_after() <= 1.0


def test_bank_cardinality():
    cfg = configs.get_smoke_config("hymba-1.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plans = plan_packing(params, max_items=3, max_seconds=1.0, split_stacked=True)
    for plan in plans.values():
        for bank in plan.banks:
            assert len(bank) <= 3
