"""Per-arch smoke tests + numerics consistency (the system invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model as M


def _batch_for(cfg, B, S, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.encoder_decoder:
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.1, jnp.float32),
            "tokens": toks[:, :16],
            "targets": toks[:, :16],
        }
    if cfg.frontend == "vision_stub":
        P = cfg.num_patches
        return {
            "patches": jnp.asarray(rng.normal(size=(B, P, cfg.d_model)) * 0.1, jnp.float32),
            "tokens": toks[:, : S - P],
            "targets": toks,
        }
    return {"tokens": toks, "targets": toks}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch, rng):
    """Reduced config: one forward/train step on CPU, shapes + no NaNs."""
    cfg = configs.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 32, rng)
    loss, metrics = jax.jit(lambda p, b: M.train_loss(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0
    grads = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_serve_shapes(arch, rng):
    cfg = configs.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch_for(cfg, B, S, rng)
    batch.pop("targets")
    cache_len = cfg.max_target_len if cfg.encoder_decoder else S + 8 + (
        cfg.num_patches if cfg.frontend == "vision_stub" else 0
    )
    cache, logits = jax.jit(lambda p, b: M.prefill(cfg, p, b, cache_len))(params, batch)
    assert logits.shape[:2] == (B, 1)
    pos = jnp.asarray(batch["tokens"].shape[1] + (cfg.num_patches if "patches" in batch else 0), jnp.int32)
    tok = jnp.zeros((B,), jnp.int32)
    cache2, logits2 = jax.jit(lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))(
        params, cache, tok, pos
    )
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize(
    "arch", ["qwen3-0.6b", "hymba-1.5b", "mamba2-1.3b", "starcoder2-7b"]
)
def test_decode_matches_full_forward(arch, rng):
    """Teacher-forced decode at position S-1 == full forward logits there."""
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32", remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache, _ = M.prefill(cfg, params, {"tokens": toks[:, : S - 1]}, S + 4)
    _, logits_dec = M.decode_step(cfg, params, cache, toks[:, S - 1], jnp.asarray(S - 1, jnp.int32))
    h, pos = M._embed_inputs(cfg, params, {"tokens": toks})
    h, _ = M.forward_hidden(cfg, params, h, pos)
    h = M.apply_norm(cfg, params["final_norm"], h)
    logits_full = M._logits(cfg, params, h)
    err = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, 0])))
    scale = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1e-9
    assert err / scale < 2e-3, f"{arch}: {err/scale}"


def test_ssd_chunked_equals_sequential(rng):
    from repro.models.mamba2 import ssm_apply, ssm_decode, ssm_init, ssm_init_cache

    cfg = dataclasses.replace(
        configs.get_smoke_config("mamba2-1.3b"), dtype="float32",
        param_dtype="float32", ssm_chunk=8,
    )
    p = ssm_init(cfg, jax.random.PRNGKey(1), jnp.float32)
    B, S = 2, 31  # deliberately not a chunk multiple
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3, jnp.float32)
    y_full = ssm_apply(cfg, p, x, jnp.float32)
    cache = ssm_init_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = ssm_decode(cfg, p, x[:, t : t + 1], cache, jnp.float32)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    rel = float(jnp.max(jnp.abs(y_full - y_seq)) / (jnp.max(jnp.abs(y_seq)) + 1e-9))
    assert rel < 1e-4


def test_blockwise_attention_matches_naive(rng):
    import repro.models.attention as A

    old = A._BLOCK_KV
    A._BLOCK_KV = 16
    try:
        q = jnp.asarray(rng.normal(size=(2, 40, 2, 3, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 40, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 40, 2, 8)), jnp.float32)
        qp = jnp.arange(40)
        for window in (0, 7):
            bias = A._mask_bias(qp, qp, window, True)
            naive = A._sdpa(q, k, v, bias)
            blk = A._sdpa_blockwise(q, k, v, qp, qp, window, True)
            assert float(jnp.max(jnp.abs(naive - blk))) < 1e-4
    finally:
        A._BLOCK_KV = old


def test_moe_dropless_matches_dense_mix(rng):
    """With capacity >= every token, grouped dispatch == explicit per-token
    top-k mixture computed densely."""
    from repro.models.moe import moe_apply, moe_init

    cfg = dataclasses.replace(
        configs.get_smoke_config("granite-moe-1b-a400m"),
        capacity_factor=8.0, dtype="float32", param_dtype="float32",
    )
    p = moe_init(cfg, jax.random.PRNGKey(3), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.3, jnp.float32)
    out, aux = moe_apply(cfg, p, x, jnp.float32)
    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    y = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["gate"][e]) * (xt @ p["up"][e])
        ye = h @ p["down"][e]
        w = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
        y = y + ye * w[:, None]
    ref = y.reshape(x.shape)
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-4
    assert 0.0 <= float(aux) < 1.0


def test_segments_cover_all_layers():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        segs = M.layer_segments(cfg)
        covered = []
        for s, e, w in segs:
            covered.extend(range(s, e))
        assert covered == list(range(cfg.n_layers))
