"""Heterogeneous OCM model: RAM kinds, inventories, kind-aware engines.

Golden costs are hand-checked:

* URAM288 is a single 72x4096 aspect: a (72, 4096) bin is exactly 1 URAM;
  (73, 4096) needs 2 (width split); (72, 4097) needs 2 (depth split).
* BRAM36 modes mirror BRAM18 at twice the depth: a (36, 1024) bin is 1
  BRAM36 (vs 2 BRAM18), a (36, 1025) bin is 2.
* On a BRAM18+URAM288 inventory the shared cost unit is 18432 bits, so one
  URAM weighs 16 units and all costs stay exactly comparable.
"""
import numpy as np
import pytest

import repro.core as c
from repro.core.ga import GeneticPacker, buffer_swap, kind_reassign
from repro.core.nfd import nfd_from_scratch, nfd_repack
from repro.core.problem import (
    BRAM18,
    BRAM36,
    LUTRAM64,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
    Solution,
    decode_chain_items,
    encode_chain_items,
    encode_chain_kinds,
    greedy_assign_kinds,
)
from repro.core.sa import SimulatedAnnealingPacker


def hetero_problem(rng, n=30, bram18=10, uram=8, max_items=4):
    bufs = [
        Buffer(
            width=int(rng.integers(1, 80)),
            depth=int(rng.integers(1, 20_000)),
            layer=int(rng.integers(0, 5)),
        )
        for _ in range(n)
    ]
    return PackingProblem(
        bufs,
        ocm=OCMInventory((BRAM18, URAM288), (bram18, uram)),
        max_items=max_items,
    )


# ------------------------------------------------------------- golden costs
def test_uram288_golden_costs():
    prob = PackingProblem(
        [Buffer(1, 1, 0)], ocm=OCMInventory((BRAM18, URAM288), (-1, -1))
    )
    uram = 1  # kind index
    assert prob.bin_primitives(72, 4096, uram) == 1
    assert prob.bin_primitives(73, 4096, uram) == 2
    assert prob.bin_primitives(72, 4097, uram) == 2
    assert prob.bin_primitives(1, 1, uram) == 1
    assert prob.bin_primitives(144, 8192, uram) == 4
    # unit weighting: gcd(18432, 294912) = 18432 -> URAM weighs 16 units
    assert prob.cost_unit_bits == 18432
    assert prob.kind_weights == (1, 16)
    assert prob.bin_cost(72, 4096, uram) == 16
    # BRAM18 lane unchanged vs the homogeneous model
    ref = PackingProblem([Buffer(1, 1, 0)])
    for w, h in [(36, 1024), (1, 16384), (7, 5000), (72, 4096)]:
        assert prob.bin_cost(w, h, 0) == ref.bin_cost(w, h)
    # best_kind: ties resolve to the lowest index (BRAM18's fine-grained
    # modes make it per-unit optimal whenever capacities are commensurate)
    assert prob.best_kind(72, 4096) == 0
    assert prob.best_kind(1, 1) == 0


def test_bram36_golden_costs():
    prob = PackingProblem(
        [Buffer(1, 1, 0)], ocm=OCMInventory((BRAM36,), (-1,))
    )
    assert prob.kind_weights == (1,)
    assert prob.cost_unit_bits == 36 * 1024
    assert prob.bin_cost(36, 1024) == 1
    assert prob.bin_cost(36, 1025) == 2
    assert prob.bin_cost(1, 32768) == 1
    assert prob.bin_cost(72, 512) == 1
    assert prob.bin_cost(2, 16500) == 2  # (2, 16384) mode: ceil(16500/16384)*1
    # joint BRAM18+BRAM36 inventory: BRAM36 weighs 2 BRAM18 units
    joint = PackingProblem(
        [Buffer(1, 1, 0)], ocm=OCMInventory((BRAM18, BRAM36), (-1, -1))
    )
    assert joint.kind_weights == (1, 2)
    assert joint.bin_cost(36, 1024, 1) == 2  # 1 primitive x weight 2


def test_lutram_gcd_unit():
    prob = PackingProblem(
        [Buffer(1, 1, 0)], ocm=OCMInventory((BRAM18, LUTRAM64), (-1, -1))
    )
    assert prob.cost_unit_bits == 64
    assert prob.kind_weights == (288, 1)
    assert prob.bin_cost(1, 64, 1) == 1  # one LUTRAM64 unit
    assert prob.bin_cost(1, 16384, 0) == 288  # one BRAM18 in LUTRAM units
    assert prob.best_kind(1, 64) == 1  # tiny buffer: LUTRAM beats a BRAM18


def test_inventory_validation_and_registry():
    with pytest.raises(ValueError):
        OCMInventory((), ())
    with pytest.raises(ValueError):
        OCMInventory((BRAM18,), (1, 2))
    with pytest.raises(ValueError):
        OCMInventory((BRAM18, BRAM18), (1, 2))
    with pytest.raises(ValueError):
        PackingProblem(
            [Buffer(1, 1, 0)],
            bram=c.BRAMSpec(),
            ocm=OCMInventory((BRAM18,), (-1,)),
        )
    inv = OCMInventory.from_counts("dev", BRAM18=4, URAM288=2)
    assert inv.kind_index("URAM288") == 1
    assert inv.capacity_units() == 4 + 2 * 16
    assert c.RAM_KINDS["URAM288"] is URAM288


def test_device_presets():
    prob = c.get_problem("RN152-W1A2", device="U50")
    assert prob.n_kinds == 2
    assert prob.name == "RN152-W1A2@U50"
    assert prob.kind_counts == (2688, 640)
    # deep ResNet overflows BRAM18 alone but fits the mixed inventory
    assert prob.singleton_solution().inventory_overflow() > 0
    sol = nfd_from_scratch(prob, np.random.default_rng(0))
    assert sol.inventory_overflow() == 0
    assert int(sol.used_primitives()[1]) > 0  # URAM actually used
    with pytest.raises(KeyError):
        c.get_ocm("ZX9000")


# -------------------------------------------------- accounting + invariants
def test_default_problem_is_single_kind():
    prob = c.get_problem("CNV-W1A1")
    assert prob.n_kinds == 1
    assert prob.kind_weights == (1,)
    assert prob.cost_unit_bits == c.BRAM18_CAPACITY_BITS
    sol = prob.singleton_solution()
    assert sol.inventory_overflow() == 0
    assert list(sol.kinds) == [0] * len(sol.bins)


def test_used_primitives_and_overflow():
    prob = PackingProblem(
        [Buffer(36, 1024, 0), Buffer(72, 4096, 1), Buffer(36, 512, 2)],
        ocm=OCMInventory((BRAM18, URAM288), (2, 1)),
        max_items=1,
    )
    sol = Solution(prob, [[0], [1], [2]], kinds=[0, 1, 0])
    np.testing.assert_array_equal(sol.used_primitives(), [3, 1])
    # 3 BRAM18 used vs 2 available -> 1 unit over; URAM within budget
    assert sol.inventory_overflow() == 1
    assert sol.cost() == 2 + 16 + 1
    assert sol.cost() == sol.cost_full()
    sol.set_kind(0, 1)  # move the (36,1024) bin to URAM
    np.testing.assert_array_equal(sol.used_primitives(), [1, 2])
    assert sol.inventory_overflow() == 16  # 2 URAM used vs 1 -> 16 units over
    assert sol.cost() == 16 + 16 + 1 == sol.cost_full()


@pytest.mark.parametrize("seed", range(6))
def test_incremental_cost_matches_full_hetero(seed):
    """Kind-aware geometry cache vs from-scratch rescan under chains of all
    three mutation operators (repack, swap with kind moves, reassign)."""
    rng = np.random.default_rng(seed)
    prob = hetero_problem(rng, n=int(rng.integers(5, 40)))
    sol = nfd_from_scratch(prob, rng, p_adm_h=0.2)
    for step in range(12):
        if step % 3 == 0:
            sol = nfd_repack(sol, rng, threshold=0.9, extra_frac=0.1, p_adm_h=0.3)
        elif step % 3 == 1:
            sol = buffer_swap(sol, rng, n_moves=3, p_kind=0.5)
        else:
            sol = kind_reassign(sol, rng, n_moves=2)
        sol.validate()
        assert sol.cost() == sol.cost_full()
        np.testing.assert_allclose(
            sol.bin_efficiencies(), sol.bin_efficiencies_full()
        )


def test_greedy_assign_kinds_relieves_overflow():
    rng = np.random.default_rng(1)
    # 20 bins of 8 BRAM18 each = 160 primitives on 40 available: must offload
    bufs = [Buffer(32, 4096, i % 3) for i in range(20)]
    prob = PackingProblem(
        bufs, ocm=OCMInventory((BRAM18, URAM288), (40, 64)), max_items=1
    )
    sol = prob.singleton_solution()
    assert sol.inventory_overflow() > 0
    greedy_assign_kinds(sol)
    sol.validate()
    assert sol.inventory_overflow() == 0
    assert sol.cost() == sol.cost_full()


def test_chain_codecs_round_trip_kinds():
    rng = np.random.default_rng(2)
    prob = hetero_problem(rng, n=12)
    sols = [nfd_from_scratch(prob, rng) for _ in range(3)]
    for s in sols:
        s.kinds[: len(s.bins) // 2] = 1
        s.invalidate()
    items, counts = encode_chain_items(sols, prob.max_items)
    kinds = encode_chain_kinds(sols, items.shape[1])
    for i, s in enumerate(sols):
        back = decode_chain_items(prob, items[i], counts[i], kinds[i])
        assert back.bins == s.bins
        assert list(back.kinds) == list(s.kinds)
        assert back.cost() == s.cost()


# ---------------------------------------------------------- engine behavior
def _tight_problem():
    bufs = [Buffer(36, 4096, i % 4) for i in range(40)]
    return PackingProblem(
        bufs, ocm=OCMInventory((BRAM18, URAM288), (40, 64)), max_items=4
    )


@pytest.mark.parametrize("algo", ["ga-nfd", "ga-s", "sa-s", "sa-nfd"])
def test_engines_reach_feasibility(algo):
    prob = _tight_problem()
    r = c.pack(prob, algo, seed=0, max_seconds=1.5, backend="python")
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost
    assert r.solution.inventory_overflow() == 0
    assert r.params["overflow"] == 0


def test_ga_backends_bit_identical_hetero():
    rng = np.random.default_rng(3)
    prob = hetero_problem(rng, n=25)
    results = {
        backend: GeneticPacker(
            backend=backend, seed=7, max_generations=15,
            max_seconds=1e9, patience=10**9,
        ).pack(prob)
        for backend in ("python", "ref", "pallas")
    }
    ref = results["python"]
    for backend, r in results.items():
        assert r.cost == ref.cost, backend
        assert r.solution.bins == ref.solution.bins, backend
        assert list(r.solution.kinds) == list(ref.solution.kinds), backend
        r.solution.validate()
        assert r.solution.cost() == r.solution.cost_full() == r.cost


def _sa(backend, prob, n_chains=1, **kw):
    kw.setdefault("seed", 5)
    kw.setdefault("max_iterations", 500)
    return SimulatedAnnealingPacker(
        perturbation="swap", backend=backend, n_chains=n_chains,
        max_seconds=1e9, patience=10**9, **kw,
    ).pack(prob)


def test_sa_single_chain_hetero_parity():
    """The scalar loop and the delta engine share the hetero RNG stream and
    exact penalty bookkeeping: identical trajectories on every backend."""
    rng = np.random.default_rng(4)
    prob = hetero_problem(rng, n=30)
    results = {b: _sa(b, prob) for b in ("legacy", "python", "ref", "pallas")}
    ref = results["legacy"]
    for backend, r in results.items():
        assert r.cost == ref.cost, backend
        assert r.solution.bins == ref.solution.bins, backend
        assert list(r.solution.kinds) == list(ref.solution.kinds), backend
        assert [cc for _, cc in r.trace] == [cc for _, cc in ref.trace], backend


def test_sa_multi_chain_hetero_backends_identical():
    rng = np.random.default_rng(5)
    prob = hetero_problem(rng, n=25)
    results = [
        _sa(b, prob, n_chains=4, seed=3, max_iterations=300, exchange_every=64)
        for b in ("python", "ref", "pallas")
    ]
    first = results[0]
    for r in results[1:]:
        assert r.cost == first.cost
        assert r.solution.bins == first.solution.bins
        assert list(r.solution.kinds) == list(first.solution.kinds)
    first.solution.validate()
    assert first.solution.cost() == first.solution.cost_full() == first.cost


def test_portfolio_hetero():
    # iteration budgets, not wall-clock: machine-independent, and no
    # TruncationWarning (promoted to an error by pytest.ini) can leak
    prob = _tight_problem()
    r = c.pack_portfolio(
        prob, n_islands=3, seed=0, max_seconds=60.0, backend="python",
        sa_chains=3, max_iterations=1500, max_generations=30,
    )
    r.solution.validate()
    assert r.solution.cost() == r.solution.cost_full() == r.cost
    assert r.cost <= prob.lower_bound() * 40  # sanity: bounded


@pytest.mark.parametrize("backend", ["ref", "pallas", "legacy"])
def test_single_kind_custom_primitive_batched_backends(backend):
    """Regression: batched GA/SA backends must evaluate a single-kind
    problem on ITS mode table, not the hardcoded BRAM18 one (a BRAM36-only
    problem used to get silently wrong costs on ref/pallas)."""
    rng = np.random.default_rng(8)
    bufs = [
        Buffer(int(rng.integers(1, 70)), int(rng.integers(1, 30_000)), int(i % 4))
        for i in range(25)
    ]
    prob = PackingProblem(bufs, ocm=OCMInventory((BRAM36,), (-1,)))
    ref = GeneticPacker(backend="python", seed=7, max_generations=12,
                        max_seconds=1e9, patience=10**9).pack(prob)
    r = GeneticPacker(backend=backend, seed=7, max_generations=12,
                      max_seconds=1e9, patience=10**9).pack(prob)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert r.solution.cost() == r.solution.cost_full() == r.cost
    sa_ref = _sa("legacy", prob, seed=9, max_iterations=300)
    sa_r = _sa(backend if backend != "legacy" else "python", prob,
               seed=9, max_iterations=300)
    assert sa_r.cost == sa_ref.cost
    assert sa_r.solution.bins == sa_ref.solution.bins


def test_default_path_rng_untouched_by_kind_params():
    """p_kind only fires on heterogeneous problems: a single-kind run with
    any p_kind matches the stock trajectory exactly."""
    prob = c.get_problem("CNV-W1A1")
    a = GeneticPacker(seed=11, max_generations=10, backend="python",
                      max_seconds=1e9, patience=10**9).pack(prob)
    b = GeneticPacker(seed=11, max_generations=10, backend="python",
                      max_seconds=1e9, patience=10**9, p_kind=0.9).pack(prob)
    assert a.cost == b.cost
    assert a.solution.bins == b.solution.bins
