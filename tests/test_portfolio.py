"""Fleet-native island portfolio: determinism, single-island bit-parity
with standalone pack(), migration semantics, and the paper-quality gate.

The load-bearing contracts (ISSUE 5 acceptance criteria):

* ``pack_portfolio(prob, seed=s, ...)`` with iteration budgets is
  bit-reproducible run-to-run — islands advance by iteration counts and
  consume per-island RNG streams, so machine speed never enters.
* A single-island portfolio is bit-identical to the corresponding
  standalone ``pack()`` run (same engines, same streams, no migration).
* Migration lands the global best in the worst warm slot of *other* live
  islands only, never touches patience counters, and never revives a
  frozen island.
"""
import warnings

import numpy as np
import pytest

import repro.core as c
from repro.core.ga import GeneticPacker
from repro.core.portfolio import _SAFleetGroup
from repro.core.sa import SimulatedAnnealingPacker

# iteration-budgeted settings: max_seconds is an outer safety cap only, so
# every run below is machine-independent and exactly reproducible
_KW = dict(max_seconds=1e9, patience=10**9, backend="python")


def _portfolio(prob, **kw):
    merged = {**_KW, **kw}
    return c.pack_portfolio(prob, **merged)


# ------------------------------------------------------------- determinism
def test_portfolio_bit_reproducible():
    """Same seed, same budgets -> identical best cost, solution, trace,
    iteration count across two runs (the acceptance pin)."""
    prob = c.get_problem("CNV-W2A2")
    kw = dict(n_islands=4, seed=0, sa_chains=4, migration_every=64,
              max_iterations=1500, max_generations=30)
    a = _portfolio(prob, **kw)
    b = _portfolio(prob, **kw)
    assert a.cost == b.cost
    assert a.solution.bins == b.solution.bins
    assert [cc for _, cc in a.trace] == [cc for _, cc in b.trace]
    assert a.iterations == b.iterations
    assert a.params["barriers"] == b.params["barriers"]
    assert a.params["migrations"] == b.params["migrations"]
    a.solution.validate()
    assert a.solution.cost() == a.solution.cost_full() == a.cost
    costs = [cc for _, cc in a.trace]
    assert all(x >= y for x, y in zip(costs, costs[1:]))


def test_portfolio_seed_changes_result_params():
    """Different seeds derive different island streams (params record them)."""
    prob = c.get_problem("CNV-W1A1")
    kw = dict(n_islands=2, sa_chains=3, max_iterations=300, max_generations=10)
    a = _portfolio(prob, seed=0, **kw)
    b = _portfolio(prob, seed=5, **kw)
    assert [i["seed"] for i in a.params["islands"]] == [0, 1]
    assert [i["seed"] for i in b.params["islands"]] == [5, 6]


# ------------------------------------------------- single-island bit-parity
def test_single_island_ga_matches_pack():
    prob = c.get_problem("CNV-W1A1")
    kw = dict(max_generations=25, **_KW)
    r = c.pack_portfolio(prob, islands=[c.IslandSpec("ga-nfd", seed=7)], **kw)
    ref = c.pack(prob, "ga-nfd", seed=7, **kw)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert r.iterations == ref.iterations


def test_single_island_sa_s_single_chain_matches_pack():
    prob = c.get_problem("CNV-W1A1")
    kw = dict(max_iterations=400, **_KW)
    r = c.pack_portfolio(prob, islands=[c.IslandSpec("sa-s", seed=5)],
                         sa_chains=1, **kw)
    ref = c.pack(prob, "sa-s", seed=5, n_chains=1, **kw)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert r.iterations == ref.iterations


def test_single_island_sa_s_multi_chain_matches_pack():
    """The fleet lane: one sa-s island IS a P == 1 `_anneal_block` fleet."""
    prob = c.get_problem("CNV-W2A2")
    kw = dict(max_iterations=500, **_KW)
    r = c.pack_portfolio(prob, islands=[c.IslandSpec("sa-s", seed=3)],
                         sa_chains=4, **kw)
    ref = c.pack(prob, "sa-s", seed=3, n_chains=4, **kw)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert [cc for _, cc in r.trace][:-1] == [cc for _, cc in ref.trace]
    assert r.iterations == ref.iterations


def test_single_island_sa_nfd_matches_pack():
    prob = c.get_problem("CNV-W1A1")
    kw = dict(max_iterations=250, **_KW)
    r = c.pack_portfolio(prob, islands=[c.IslandSpec("sa-nfd", seed=2)], **kw)
    ref = c.pack(prob, "sa-nfd", seed=2, **kw)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert r.iterations == ref.iterations


def test_hetero_single_island_parity_bounded_inventory():
    """Hetero-device portfolio on a bounded inventory: the single-island
    fleet reproduces the standalone hetero trajectory incl. kind lanes."""
    prob = c.get_problem("CNV-W1A1", device="U50")
    kw = dict(max_iterations=400, **_KW)
    r = c.pack_portfolio(prob, islands=[c.IslandSpec("sa-s", seed=4)],
                         sa_chains=3, **kw)
    ref = c.pack(prob, "sa-s", seed=4, n_chains=3, **kw)
    assert r.cost == ref.cost
    assert r.solution.bins == ref.solution.bins
    assert list(r.solution.kinds) == list(ref.solution.kinds)
    r.solution.validate()


def test_hetero_portfolio_deterministic():
    prob = c.get_problem("CNV-W2A2", device="ZU7EV")
    kw = dict(n_islands=3, seed=0, sa_chains=3, max_iterations=600,
              max_generations=12)
    a = _portfolio(prob, **kw)
    b = _portfolio(prob, **kw)
    assert a.cost == b.cost
    assert a.solution.bins == b.solution.bins
    assert list(a.solution.kinds) == list(b.solution.kinds)
    assert [cc for _, cc in a.trace] == [cc for _, cc in b.trace]
    a.solution.validate()


# --------------------------------------------------------------- migration
def _fleet_of_two(prob, packer, seeds=(0, 1)):
    return _SAFleetGroup(
        packer, prob, [np.random.default_rng(s) for s in seeds], "python"
    )


def test_migrant_replaces_worst_warm_slot():
    """`_block_migrate` lands a strictly-better migrant in the island's
    worst chain slot (and only then)."""
    prob = c.get_problem("CNV-W1A1")
    packer = SimulatedAnnealingPacker(
        perturbation="swap", backend="python", n_chains=3, seed=0,
        max_seconds=1e9, patience=10**9, max_iterations=10**6,
    )
    packer._hetero = False
    fleet = _fleet_of_two(prob, packer)
    fleet.advance(100)
    st = fleet.st
    # a migrant strictly better than island 1's worst chain: use the global
    # best of island 0 after more annealing than island 1 has seen
    better = c.pack(prob, "sa-s", seed=9, n_chains=4, max_iterations=2000,
                    **_KW).solution
    lo = packer.n_chains  # island 1's rows
    worst = lo + int(st.pcosts[lo : lo + 3].argmax())
    worst_before = int(st.pcosts[worst])
    assert better.cost() < worst_before
    stale_before = st.stale.copy()
    assert packer._block_migrate(st, 1, better)
    assert int(st.pcosts[worst]) == better.cost()
    assert int(st.costs[worst]) == better.cost()
    # patience counters are untouched (migration cannot revive anything)
    np.testing.assert_array_equal(st.stale, stale_before)
    # a migrant that does not strictly beat the worst slot is refused
    assert not packer._block_migrate(st, 1, prob.singleton_solution())


def test_migration_never_revives_frozen_island():
    """A frozen fleet island refuses migrants outright: its rows stop
    changing and it draws no further RNG (the standalone-trajectory rule)."""
    prob = c.get_problem("CNV-W1A1")
    packer = SimulatedAnnealingPacker(
        perturbation="swap", backend="python", n_chains=2, seed=0,
        max_seconds=1e9, patience=30, max_iterations=10**6,
    )
    packer._hetero = False
    fleet = _fleet_of_two(prob, packer)
    fleet.advance(None)  # runs until both islands freeze
    st = fleet.st
    assert st.frozen and st.done
    better = c.pack(prob, "sa-s", seed=9, n_chains=4, max_iterations=2000,
                    **_KW).solution
    items_before = st.items.copy()
    assert not packer._block_migrate(st, 0, better)
    assert not packer._block_migrate(st, 1, better)
    np.testing.assert_array_equal(st.items, items_before)


def test_scalar_and_ga_migrate_hooks_respect_frozen_and_strictness():
    prob = c.get_problem("CNV-W1A1")
    better = c.pack(prob, "sa-s", seed=9, n_chains=4, max_iterations=3000,
                    **_KW).solution
    # scalar SA island
    sa = SimulatedAnnealingPacker(perturbation="nfd", seed=0, max_seconds=1e9,
                                  patience=50, max_iterations=10**6)
    sa._hetero = False
    st = sa._scalar_start(prob, None)
    sa._scalar_run(st, 20)
    stale_before, trace_before = st.stale, len(st.trace)
    assert sa._scalar_migrate(st, better)  # live + strictly better
    assert st.cost == better.cost()
    # the patience-reference best absorbs the migrant silently: no stale
    # reset (directly or via the next improved-check), no trace entry
    assert st.best_cost == better.cost()
    assert st.stale == stale_before and len(st.trace) == trace_before
    assert not sa._scalar_migrate(st, better)  # not strictly better now
    sa._scalar_run(st)  # drain until frozen (patience)
    assert st.done
    prev = st.cost
    assert not sa._scalar_migrate(st, prob.singleton_solution())
    assert st.cost == prev
    # GA island
    ga = GeneticPacker(seed=0, backend="python", max_seconds=1e9,
                       patience=10**9, max_generations=10**6)
    run = ga._start_run(prob, np.random.default_rng(0), None, "python")
    ga._eval_init(run, None)
    sel_before = run.costs.copy()
    worst = int(np.argmax(run.costs))
    stale_before, trace_before = run.stale, len(run.trace)
    assert ga._migrate_in(run, better)
    assert run.costs[worst] == better.cost()
    assert run.costs[worst] < sel_before[worst]
    # best-tracking absorbed the migrant without a trace entry or stale
    # reset, so the next _track_best cannot revive the run's patience
    assert run.best_cost == better.cost()
    assert run.stale == stale_before and len(run.trace) == trace_before
    ga._track_best(run)
    assert run.stale == stale_before + 1  # migrant is NOT an own improvement
    run.done = True
    assert not ga._migrate_in(run, prob.singleton_solution())


def test_migration_disabled_sums_standalone_runs():
    """``migration_every=0`` makes islands fully independent: the portfolio
    equals the best of the standalone runs and sums their iterations."""
    prob = c.get_problem("CNV-W1A1")
    kw = dict(max_iterations=400, max_generations=15, **_KW)
    specs = [c.IslandSpec("ga-nfd", seed=0), c.IslandSpec("sa-s", seed=1)]
    r = c.pack_portfolio(prob, islands=specs, sa_chains=3,
                         migration_every=0, **kw)
    ga = c.pack(prob, "ga-nfd", seed=0, **kw)
    sa = c.pack(prob, "sa-s", seed=1, n_chains=3, **kw)
    assert r.cost == min(ga.cost, sa.cost)
    assert r.iterations == ga.iterations + sa.iterations
    assert r.params["migrations"] == 0


# ------------------------------------------------------------- API plumbing
def test_max_workers_deprecated():
    prob = c.get_problem("CNV-W1A1")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        r = _portfolio(prob, n_islands=1, seed=0, max_generations=5,
                       max_workers=2)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    r.solution.validate()


def test_portfolio_through_pack_and_sweep():
    """api.pack routes 'portfolio'; a pack_sweep candidate can itself be a
    portfolio (serial lane) and — being deterministic now — matches the
    direct call exactly."""
    probs = [c.get_problem("CNV-W1A1"), c.get_problem("CNV-W2A2")]
    kw = dict(n_islands=2, sa_chains=3, max_iterations=300,
              max_generations=10, **_KW)
    sw = c.pack_sweep(probs, "portfolio", seed=0, max_seconds=1e9,
                      backend="python", n_islands=2, sa_chains=3,
                      max_iterations=300, max_generations=10, patience=10**9)
    for prob, r in zip(probs, sw.results):
        ref = c.pack_portfolio(prob, seed=0, **kw)
        assert r.cost == ref.cost, prob.name
        assert r.solution.bins == ref.solution.bins, prob.name


def test_portfolio_threads_legacy_still_works():
    prob = c.get_problem("CNV-W1A1")
    r = c.pack_portfolio_threads(prob, n_islands=2, seed=0, max_seconds=0.8,
                                 backend="python", sa_chains=3)
    r.solution.validate()
    assert r.algorithm.startswith("portfolio-threads[")
    assert r.params["rounds"] >= 1


# ------------------------------------------------------ paper-quality gate
# Golden single-engine baselines (recorded from seeded, iteration-budgeted
# runs of this repo): the portfolio must never do worse than the single
# engine it hedges.  Budgets are iteration counts, so the gate is
# machine-independent; regressions in either the engines or the portfolio
# trip it.
_QUALITY_GOLDEN = {
    # name: (ga-nfd golden cost @ max_generations, portfolio max_iterations)
    "CNV-W1A1": (95, 120, 6000),
    "RN50-W1A2": (1412, 40, 6000),
}


@pytest.mark.slow
@pytest.mark.parametrize("name", list(_QUALITY_GOLDEN))
def test_portfolio_quality_gate(name):
    golden, gens, iters = _QUALITY_GOLDEN[name]
    prob = c.get_problem(name)
    hp = c.hyperparams(name)
    base = c.pack(prob, "ga-nfd", seed=0, max_generations=gens, **_KW, **hp)
    assert base.cost == golden, (
        f"single-engine baseline moved: {base.cost} != recorded {golden}"
    )
    islands = [c.IslandSpec("ga-nfd", seed=0), c.IslandSpec("sa-s", seed=1),
               c.IslandSpec("sa-nfd", seed=2)]
    r = c.pack_portfolio(prob, islands=islands, sa_chains=8,
                         migration_every=64, max_generations=gens,
                         max_iterations=iters, **_KW, **hp)
    r.solution.validate()
    assert prob.lower_bound() <= r.cost <= golden
