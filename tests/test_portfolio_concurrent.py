"""Concurrent heterogeneous barrier execution: bit-parity with the serial
loop (ISSUE 7 acceptance pins).

The scheduler contract: ``scheduler="concurrent"`` (side-lane threads for
the scalar/GA groups, device-dispatch main lane for the SA fleet, optional
fused fleet+GA dispatch) changes WALL-CLOCK ONLY.  Every island still
consumes exactly its own RNG stream against disjoint state, so the final
cost, packing, improvement-trace cost sequence, migration decisions, and
iteration counts are bit-identical to ``scheduler="serial"`` (the PR-5
reference loop) — for every lineup in the bench matrix, on hetero-OCM
problems, with forced fused dispatch, and across a checkpoint/resume cut
mid-run.  Wall-clock values (``barrier_seconds``/``group_seconds``, the
wall-time-ordered merged trace *times*) are exempt.
"""
import numpy as np
import pytest

from faultinject import SimulatedCrash, crash_at
from repro.core import IslandSpec, pack_portfolio
from repro.core.portfolio import pack_portfolio_threads
from repro.core.problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
)

# iteration-budgeted: machine speed never enters, runs are bit-reproducible
_KW = dict(
    max_seconds=1e9, patience=10**9, backend="python", sa_chains=4,
    migration_every=32, max_iterations=400, max_generations=8,
)

# the bench lineup matrix (benchmarks/bench_engine.py run_portfolio)
_LINEUPS = {
    "sa-fleet": ("sa-s",),
    "mixed": ("ga-nfd", "sa-s", "sa-nfd"),
    "ga-heavy": ("ga-nfd", "ga-nfd", "ga-nfd", "sa-s"),
    "scalar-heavy": ("sa-nfd", "sa-nfd", "sa-nfd", "sa-s"),
}


def _problem(seed: int, hetero: bool = False) -> PackingProblem:
    rng = np.random.default_rng(seed)
    bufs = [
        Buffer(width=int(rng.integers(1, 80)),
               depth=int(rng.integers(1, 40_000)),
               layer=int(rng.integers(0, 5)))
        for _ in range(int(rng.integers(14, 28)))
    ]
    ocm = (
        OCMInventory((BRAM18, URAM288), (len(bufs) * 3, 8), name=f"dev{seed}")
        if hetero else None
    )
    return PackingProblem(bufs, max_items=4, name=f"cp{seed}", ocm=ocm)


def _record(res):
    """Everything the parity contract covers, nothing wall-clock."""
    return (
        res.cost, res.solution.state_dict(), res.iterations,
        [c for _, c in res.trace], res.params["barriers"],
        res.params["migrations"], res.params["strides"],
    )


def _run(prob, lineup, **kw):
    merged = {**_KW, "n_islands": len(lineup) + 1, "algorithms": lineup, **kw}
    return pack_portfolio(prob, **merged)


# ------------------------------------------------------- scheduler bit-parity
@pytest.mark.parametrize("name", sorted(_LINEUPS))
def test_concurrent_matches_serial(name):
    """The acceptance pin: concurrent == serial, bit for bit, for every
    lineup in the bench matrix."""
    prob = _problem(21)
    lineup = _LINEUPS[name]
    a = _run(prob, lineup, scheduler="serial")
    b = _run(prob, lineup, scheduler="concurrent")
    assert _record(a) == _record(b)
    assert a.params["scheduler"] == "serial"
    assert b.params["scheduler"] == "concurrent"


def test_concurrent_matches_serial_hetero_ocm():
    """Same pin on a heterogeneous-OCM problem: kind lanes and the
    inventory-penalized migration comparisons ride the side lane too."""
    prob = _problem(22, hetero=True)
    a = _run(prob, _LINEUPS["mixed"], scheduler="serial")
    b = _run(prob, _LINEUPS["mixed"], scheduler="concurrent")
    assert _record(a) == _record(b)


def test_concurrent_is_reproducible_run_to_run():
    prob = _problem(23)
    a = _run(prob, _LINEUPS["mixed"], scheduler="concurrent")
    b = _run(prob, _LINEUPS["mixed"], scheduler="concurrent")
    assert _record(a) == _record(b)


# ------------------------------------------------------------- fused dispatch
def test_fused_forced_matches_serial():
    """Forcing fused dispatch on the numpy backend exercises the fused
    fleet+GA driver without JAX: still bit-identical to the serial loop."""
    prob = _problem(24)
    a = _run(prob, _LINEUPS["mixed"], scheduler="serial")
    b = _run(prob, _LINEUPS["mixed"], scheduler="concurrent", fused=True)
    assert _record(a) == _record(b)
    assert a.params["fused"] is False
    assert b.params["fused"] is True
    assert any(k.endswith(":fused") for k in b.params["group_seconds"])


def test_fused_ref_backend_matches_serial():
    """The jax path: ref-backend fused barriers (one jit'd device program
    per segment) leave the trajectory untouched, hetero kinds included."""
    prob = _problem(25, hetero=True)
    kw = dict(backend="ref", migration_every=16, max_iterations=200,
              max_generations=5, sa_chains=3)
    a = _run(prob, _LINEUPS["mixed"], scheduler="serial", **kw)
    b = _run(prob, _LINEUPS["mixed"], scheduler="concurrent", fused=True, **kw)
    assert _record(a) == _record(b)
    assert b.params["fused"] is True


def test_fused_stays_off_on_python_backend():
    """Auto-fuse requires both engines on a jax backend: the CPU default
    (numpy SA) keeps the fused path off unless forced."""
    prob = _problem(26)
    r = _run(prob, _LINEUPS["mixed"], scheduler="concurrent")
    assert r.params["fused"] is False


# -------------------------------------------------- checkpoint/resume parity
def _resume_record(res):
    """The PR-6 resume contract: the merged trace is wall-time-ordered and
    rebuilt from restored state, so (like test_resume.py) it is exempt."""
    r = _record(res)
    return r[:3] + r[4:]


def test_checkpoint_resume_mid_barrier_concurrent(tmp_path):
    """A concurrent run killed at a mid-run barrier resumes — still
    concurrent — to the bit-identical result of an uninterrupted serial
    run (scheduler/fused are dispatch-only: not part of the snapshot
    identity, so they may even differ across the cut)."""
    prob = _problem(27)
    ref = _resume_record(_run(prob, _LINEUPS["mixed"], scheduler="serial"))
    with pytest.raises(SimulatedCrash):
        _run(prob, _LINEUPS["mixed"], scheduler="concurrent",
             checkpoint_dir=tmp_path, checkpoint_every=2,
             on_checkpoint=crash_at(2))
    resumed = _run(prob, _LINEUPS["mixed"], scheduler="concurrent",
                   checkpoint_dir=tmp_path, resume=True)
    assert _resume_record(resumed) == ref


def test_serial_resume_of_concurrent_checkpoint(tmp_path):
    prob = _problem(28)
    ref = _resume_record(
        _run(prob, _LINEUPS["scalar-heavy"], scheduler="serial")
    )
    with pytest.raises(SimulatedCrash):
        _run(prob, _LINEUPS["scalar-heavy"], scheduler="concurrent",
             checkpoint_dir=tmp_path, checkpoint_every=2,
             on_checkpoint=crash_at(1))
    resumed = _run(prob, _LINEUPS["scalar-heavy"], scheduler="serial",
                   checkpoint_dir=tmp_path, resume=True)
    assert _resume_record(resumed) == ref


# ------------------------------------------------------- strides and timing
def test_strides_recorded_and_static():
    """Per-family strides are a pure function of lineup + migration_every
    (never machine speed): pinned literally for the mixed lineup."""
    prob = _problem(29)
    r = _run(prob, _LINEUPS["mixed"])
    # 4 islands over (ga-nfd, sa-s, sa-nfd) -> 2 GA islands, so the
    # delta-kernel fleet stride carries the x2 GA-island multiplier
    assert r.params["strides"] == {"g0:scalar": 16, "g1:ga": 1, "g2:fleet": 64}


def test_homogeneous_lineup_keeps_uniform_stride():
    prob = _problem(30)
    r = _run(prob, _LINEUPS["sa-fleet"])
    assert r.params["strides"] == {"g0:fleet": 32}


def test_timing_params_present():
    prob = _problem(31)
    r = _run(prob, _LINEUPS["mixed"], scheduler="concurrent")
    assert len(r.params["barrier_seconds"]) == r.params["barriers"]
    assert all(t >= 0.0 for t in r.params["barrier_seconds"])
    assert set(r.params["group_seconds"]) == set(r.params["strides"])
    assert all(t >= 0.0 for t in r.params["group_seconds"].values())


def test_unknown_scheduler_rejected():
    with pytest.raises(ValueError, match="scheduler"):
        pack_portfolio(_problem(32), scheduler="threads", **_KW)


# ------------------------------------------------- legacy threads = baseline
def test_threads_engine_is_baseline_only():
    """pack_portfolio_threads is the wall-clock benchmark baseline, not a
    supported execution path: no determinism, scheduler, or checkpoint
    surface — pinned so nobody quietly grows one."""
    doc = pack_portfolio_threads.__doc__
    assert "baseline" in doc
    import inspect

    params = inspect.signature(pack_portfolio_threads).parameters
    for absent in ("scheduler", "fused", "checkpoint_dir", "resume"):
        assert absent not in params
