"""Self-tuning portfolio: successive-halving racing (pack_portfolio(auto=True)).

The racing contract (docs/DESIGN.md section 16):

* bit-reproducible — same seed, same grid, same ledger => identical
  trajectory, eliminations, and final packing, run to run;
* a single-entry race grid is bit-identical to the equivalent plain
  lineup (the racing driver adds no trajectory of its own);
* the ledger is a hard cap — ``spent <= budget`` always, and charging is
  whole-barrier (the race never overdraws mid-barrier);
* elimination does not perturb survivors' RNG streams (concurrent and
  serial schedulers agree bit-exactly);
* a race killed mid-flight resumes to the identical eliminations and
  final cost (fault-injection, same contract as tests/test_resume.py);
* at equal TOTAL iteration budget the auto-tuned portfolio is no worse
  than the default lineup it replaces (pinned slow test on the paper's
  Table 3/4 accelerators).
"""
import numpy as np
import pytest

from faultinject import SimulatedCrash, crash_at
from repro.core import (
    DEFAULT_RACE_GRID,
    IslandSpec,
    get_problem,
    pack_portfolio,
)
from repro.core.problem import Buffer, PackingProblem

# deterministic engines: iteration budgets terminate, wall/patience parked
_KW = dict(max_seconds=1e9, patience=10**9, backend="python")


def _problem(seed: int = 11) -> PackingProblem:
    rng = np.random.default_rng(seed)
    bufs = [
        Buffer(width=int(rng.integers(1, 80)), depth=int(rng.integers(1, 40_000)),
               layer=int(rng.integers(0, 5)))
        for _ in range(int(rng.integers(16, 28)))
    ]
    return PackingProblem(bufs, max_items=4, name=f"race{seed}")


# small, cheap grid exercising both engine families and the scalar lane
_GRID = [
    ("sa-s", {"n_chains": 4}),
    ("sa-s", {"n_chains": 2, "ladder_max": 8.0}),
    ("ga-nfd", {"n_pop": 10}),
    ("sa-nfd", {}),
]
_RACE = dict(_KW, auto=True, race_grid=_GRID, race_budget=6000, race_final=2,
             migration_every=32, seed=3)


def _record(res):
    """Everything the bit-reproducibility contract covers."""
    race = res.params["race"]
    return (
        res.cost, res.solution.state_dict(), res.iterations,
        res.params["barriers"], res.params["migrations"],
        race["spent"], tuple(race["survivors"]),
        tuple((e["island"], e["barrier"]) for e in race["eliminated"]),
    )


# ------------------------------------------------------------- API validation
def test_race_grid_without_auto_raises():
    with pytest.raises(ValueError, match="auto=True"):
        pack_portfolio(_problem(), race_grid=_GRID, **_KW)
    with pytest.raises(ValueError, match="auto=True"):
        pack_portfolio(_problem(), race_budget=1000, **_KW)


def test_auto_with_explicit_islands_raises():
    with pytest.raises(ValueError, match="not both"):
        pack_portfolio(_problem(), auto=True,
                       islands=[IslandSpec("sa-s", seed=0)], **_KW)


def test_default_race_grid_shape():
    # entries are (algorithm, hyper-overrides) pairs over both engine families
    assert len(DEFAULT_RACE_GRID) >= 8
    algos = {a for a, _ in DEFAULT_RACE_GRID}
    assert "sa-s" in algos and "ga-nfd" in algos
    assert all(isinstance(h, dict) for _, h in DEFAULT_RACE_GRID)


# --------------------------------------------------------------- determinism
@pytest.fixture(scope="module")
def race_ref():
    return _record(pack_portfolio(_problem(), **_RACE))


def test_racing_is_bit_reproducible(race_ref):
    assert _record(pack_portfolio(_problem(), **_RACE)) == race_ref


def test_racing_ledger_is_respected_and_spent(race_ref):
    res = pack_portfolio(_problem(), **_RACE)
    race = res.params["race"]
    assert race["budget"] == 6000
    assert 0 < race["spent"] <= race["budget"]
    # whole-barrier charging: the shortfall is less than one barrier's worth
    # of the surviving live set (the race stops rather than overdraw)
    barrier_cost = sum(race["work"][k] for k in race["survivors"])
    assert race["budget"] - race["spent"] < barrier_cost
    assert res.params["truncated_by_wallclock"] is False


def test_racing_halves_to_final_k(race_ref):
    res = pack_portfolio(_problem(), **_RACE)
    race = res.params["race"]
    # 4 configs, final_k=2: exactly one halving eliminates two islands
    assert len(race["survivors"]) == 2
    assert len(race["eliminated"]) == 2
    assert sorted(
        race["survivors"] + [e["island"] for e in race["eliminated"]]
    ) == [0, 1, 2, 3]
    # eliminations happen at a recorded barrier with the losing value pinned
    assert all(e["barrier"] >= 1 and e["value"] >= 0 for e in race["eliminated"])


def test_racing_concurrent_matches_serial(race_ref):
    got = _record(pack_portfolio(_problem(), scheduler="serial", **_RACE))
    assert got == race_ref


def test_racing_default_budget_equals_default_lineup_work():
    # race_budget=None anchors the ledger to the work the default lineup
    # would consume under the same budgets — auto never spends more than
    # the lineup it replaces
    kw = dict(_KW, seed=3, migration_every=32, max_iterations=256,
              max_generations=8, sa_chains=4)
    res = pack_portfolio(_problem(), auto=True, race_grid=_GRID[:2], **kw)
    race = res.params["race"]
    assert race["budget"] > 0
    assert race["spent"] <= race["budget"]


# ------------------------------------------------- single-entry grid == plain
def test_single_entry_grid_matches_plain_lineup():
    """A race of one config has nobody to eliminate: the racing driver must
    reduce exactly to the plain portfolio, bit for bit."""
    prob = _problem()
    seg, chains, budget = 32, 4, 4096
    barriers = budget // (seg * chains)
    auto = pack_portfolio(
        prob, auto=True, race_grid=[("sa-s", {"n_chains": chains})],
        race_budget=budget, migration_every=seg, seed=0, **_KW,
    )
    plain = pack_portfolio(
        prob, islands=[IslandSpec("sa-s", seed=0, hyper={"n_chains": chains})],
        migration_every=seg, max_iterations=barriers * seg, seed=0, **_KW,
    )
    assert auto.cost == plain.cost
    assert auto.iterations == plain.iterations
    assert auto.solution.state_dict() == plain.solution.state_dict()
    assert [c for _, c in auto.trace] == [c for _, c in plain.trace]
    assert auto.params["race"]["survivors"] == [0]
    assert auto.params["race"]["eliminated"] == []


# ------------------------------------------------------ crash/resume mid-race
def test_race_killed_mid_flight_resumes_bit_identical(tmp_path, race_ref):
    # crash late enough that eliminations already happened (the race state —
    # ledger position AND the elimination replay list — must ride the
    # snapshot, not just the engine states)
    kw = dict(_RACE, checkpoint_dir=tmp_path, checkpoint_every=2)
    with pytest.raises(SimulatedCrash):
        pack_portfolio(_problem(), on_checkpoint=crash_at(6), **kw)
    resumed = pack_portfolio(_problem(), resume=True, **kw)
    assert _record(resumed) == race_ref


@pytest.mark.parametrize("kill_after", [1, 3])
def test_race_killed_early_resumes_bit_identical(tmp_path, race_ref, kill_after):
    kw = dict(_RACE, checkpoint_dir=tmp_path, checkpoint_every=1)
    with pytest.raises(SimulatedCrash):
        pack_portfolio(_problem(), on_checkpoint=crash_at(kill_after), **kw)
    resumed = pack_portfolio(_problem(), resume=True, **kw)
    assert _record(resumed) == race_ref


def test_race_checkpointing_is_trajectory_neutral(tmp_path, race_ref):
    got = pack_portfolio(_problem(), checkpoint_dir=tmp_path,
                         checkpoint_every=2, **_RACE)
    assert _record(got) == race_ref


# ------------------------------------- deliverable: auto beats default lineup
@pytest.mark.slow
@pytest.mark.parametrize("accel", ["CNV-W1A1", "CNV-W2A2"])
def test_auto_no_worse_than_default_at_equal_total_budget(accel):
    """The PR deliverable, pinned: at equal TOTAL iteration budget the
    self-tuned portfolio matches or beats the default same-size lineup on
    the paper's Table 3/4 accelerators.  SA-only lineups keep the work
    ledger in raw chain-step units so "equal budget" is exact."""
    prob = get_problem(accel)
    grid = [
        ("sa-s", {"n_chains": 4}),
        ("sa-s", {"n_chains": 4, "ladder_max": 8.0}),
        ("sa-s", {"n_chains": 4, "sa_t0": 60.0, "sa_rc": 0.5}),
        ("sa-s", {"n_chains": 4, "sa_t0": 10.0, "sa_rc": 2.0}),
    ]
    kw = dict(_KW, seed=0, migration_every=32, sa_chains=4,
              n_islands=4, algorithms=("sa-s",), max_iterations=512)
    # ledger defaults to the default lineup's total work: 4 islands x 512
    # iterations x 4 chains of raw chain-steps each
    auto = pack_portfolio(prob, auto=True, race_grid=grid, **kw)
    default = pack_portfolio(prob, **kw)
    assert auto.params["race"]["budget"] == 4 * 512 * 4
    assert auto.params["race"]["spent"] <= auto.params["race"]["budget"]
    assert auto.cost <= default.cost
