"""Crash-safe sweeps: bit-exact restart parity under fault injection.

The acceptance contract (docs/DESIGN.md section 12): a checkpointed
``pack_sweep`` / ``pack_portfolio`` killed at ANY barrier — including with
its newest snapshot corrupted afterwards — resumes to the bit-identical
final best cost, packing, iteration counts, and improvement-trace cost
sequence of a same-seed uninterrupted run.  Wall-clock values (and the
portfolio's wall-time-ordered merged trace) are exempt.

Crashes here are in-process ``SimulatedCrash`` raises from the
``on_checkpoint`` hook (tests/faultinject.py); the CI resume-smoke lane
repeats the experiment with a real SIGKILL via ``tools/sweep_resume.py``.
"""
import numpy as np
import pytest

from faultinject import (
    SimulatedCrash,
    corrupt_arrays,
    corrupt_manifest,
    crash_at,
    latest_step_dir,
    tear_arrays,
)
from repro.core import IslandSpec, pack_portfolio, pack_sweep
from repro.core.problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
)

# deterministic engines: iteration budgets terminate, wall/patience parked
_KW = dict(max_seconds=1e9, patience=10**9)
_SA = dict(_KW, backend="python", max_iterations=600, n_chains=4)
_GA = dict(_KW, backend="ref", max_generations=12, n_pop=12)


def _problem(seed: int, hetero: bool = False) -> PackingProblem:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 30))
    bufs = [
        Buffer(width=int(rng.integers(1, 80)), depth=int(rng.integers(1, 40_000)),
               layer=int(rng.integers(0, 5)))
        for _ in range(n)
    ]
    ocm = (
        OCMInventory((BRAM18, URAM288), (n * 3, 8), name=f"dev{seed}")
        if hetero else None
    )
    return PackingProblem(bufs, max_items=4, name=f"rp{seed}", ocm=ocm)


@pytest.fixture(scope="module")
def problems():
    return [_problem(s) for s in (11, 12, 13)]


@pytest.fixture(scope="module")
def sweep_ref(problems):
    return _sweep_record(pack_sweep(problems, "sa-s", seed=3, **_SA))


@pytest.fixture(scope="module")
def ga_sweep_ref(problems):
    return _sweep_record(pack_sweep(problems, "ga-nfd", seed=7, **_GA))


# one island per engine codec: GA lockstep, SA fleet, scalar loop, single-chain
_ISLANDS = [
    IslandSpec("ga-nfd", seed=5),
    IslandSpec("sa-s", seed=6),
    IslandSpec("sa-nfd", seed=7),
    IslandSpec("sa-s", seed=8, hyper={"n_chains": 1}),
]
_PORT = dict(_KW, backend="ref", migration_every=32, max_iterations=400,
             max_generations=10, sa_chains=4)


@pytest.fixture(scope="module")
def portfolio_ref(problems):
    return _portfolio_record(
        pack_portfolio(problems[0], islands=_ISLANDS, **_PORT)
    )


def _sweep_record(sw):
    """Everything the parity contract covers, nothing wall-clock."""
    return [
        (r.cost, r.solution.state_dict(), r.iterations,
         [c for _, c in r.trace])
        for r in sw.results
    ]


def _portfolio_record(res):
    return (
        res.cost, res.solution.state_dict(), res.iterations,
        res.params["barriers"], res.params["migrations"],
    )


# ------------------------------------------------------------------ pack_sweep
def test_sweep_checkpointing_is_trajectory_neutral(problems, sweep_ref, tmp_path):
    got = pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                     checkpoint_every=150, **_SA)
    assert _sweep_record(got) == sweep_ref


@pytest.mark.parametrize("kill_after", [1, 2, 3])
def test_sweep_sa_killed_at_barrier_resumes_bit_identical(
    problems, sweep_ref, tmp_path, kill_after
):
    with pytest.raises(SimulatedCrash):
        pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                   checkpoint_every=150, on_checkpoint=crash_at(kill_after),
                   **_SA)
    resumed = pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                         checkpoint_every=150, resume=True, **_SA)
    assert _sweep_record(resumed) == sweep_ref


@pytest.mark.parametrize("damage", [tear_arrays, corrupt_arrays, corrupt_manifest])
def test_sweep_resume_with_corrupted_latest_checkpoint(
    problems, sweep_ref, tmp_path, damage
):
    # killed at barrier 3, then the newest snapshot is damaged on disk: the
    # resume must fall back to the older intact snapshot and STILL land on
    # the bit-identical final result (engines are deterministic from any
    # barrier state, so replaying a longer tail changes nothing)
    with pytest.raises(SimulatedCrash):
        pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                   checkpoint_every=150, on_checkpoint=crash_at(3), **_SA)
    damage(latest_step_dir(tmp_path))
    resumed = pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                         checkpoint_every=150, resume=True, **_SA)
    assert _sweep_record(resumed) == sweep_ref


@pytest.mark.parametrize("kill_after", [1, 2])
def test_sweep_ga_killed_at_barrier_resumes_bit_identical(
    problems, ga_sweep_ref, tmp_path, kill_after
):
    with pytest.raises(SimulatedCrash):
        pack_sweep(problems, "ga-nfd", seed=7, checkpoint_dir=tmp_path,
                   checkpoint_every=4, on_checkpoint=crash_at(kill_after),
                   **_GA)
    resumed = pack_sweep(problems, "ga-nfd", seed=7, checkpoint_dir=tmp_path,
                         checkpoint_every=4, resume=True, **_GA)
    assert _sweep_record(resumed) == ga_sweep_ref


def test_sweep_serial_lane_resumes_per_candidate(problems, tmp_path):
    # sa-nfd has no batched lane: checkpoints are whole completed candidates
    kw = dict(_KW, backend="python", max_iterations=250)
    ref = _sweep_record(pack_sweep(problems, "sa-nfd", seed=2, **kw))
    with pytest.raises(SimulatedCrash):
        pack_sweep(problems, "sa-nfd", seed=2, checkpoint_dir=tmp_path,
                   on_checkpoint=crash_at(2), **kw)
    resumed = pack_sweep(problems, "sa-nfd", seed=2, checkpoint_dir=tmp_path,
                         resume=True, **kw)
    assert _sweep_record(resumed) == ref
    assert resumed.n_solved == 1  # two of three came from the snapshot
    assert resumed.cache_hits == 2


def test_sweep_completed_checkpoint_serves_everything(problems, sweep_ref, tmp_path):
    pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
               checkpoint_every=150, **_SA)
    again = pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                       checkpoint_every=150, resume=True, **_SA)
    assert again.n_solved == 0
    assert _sweep_record(again) == sweep_ref


def test_sweep_resume_refuses_mismatched_config(problems, tmp_path):
    with pytest.raises(SimulatedCrash):
        pack_sweep(problems, "sa-s", seed=3, checkpoint_dir=tmp_path,
                   checkpoint_every=150, on_checkpoint=crash_at(1), **_SA)
    with pytest.raises(ValueError, match="differently-configured"):
        pack_sweep(problems, "sa-s", seed=4, checkpoint_dir=tmp_path,
                   checkpoint_every=150, resume=True, **_SA)


def test_sweep_hetero_crash_resume(tmp_path):
    # heterogeneous OCM: kind lanes + inventory arrays ride the same codecs
    probs = [_problem(s, hetero=True) for s in (21, 22)]
    kw = dict(_KW, backend="python", max_iterations=400, n_chains=4)
    ref = _sweep_record(pack_sweep(probs, "sa-s", seed=5, **kw))
    with pytest.raises(SimulatedCrash):
        pack_sweep(probs, "sa-s", seed=5, checkpoint_dir=tmp_path,
                   checkpoint_every=120, on_checkpoint=crash_at(2), **kw)
    resumed = pack_sweep(probs, "sa-s", seed=5, checkpoint_dir=tmp_path,
                         checkpoint_every=120, resume=True, **kw)
    assert _sweep_record(resumed) == ref


# -------------------------------------------------------------- pack_portfolio
def test_portfolio_checkpointing_is_trajectory_neutral(
    problems, portfolio_ref, tmp_path
):
    got = pack_portfolio(problems[0], islands=_ISLANDS,
                         checkpoint_dir=tmp_path, checkpoint_every=2, **_PORT)
    assert _portfolio_record(got) == portfolio_ref
    assert got.params["truncated_by_wallclock"] is False


@pytest.mark.parametrize("kill_after", [1, 2, 3])
def test_portfolio_killed_at_barrier_resumes_bit_identical(
    problems, portfolio_ref, tmp_path, kill_after
):
    with pytest.raises(SimulatedCrash):
        pack_portfolio(problems[0], islands=_ISLANDS, checkpoint_dir=tmp_path,
                       checkpoint_every=2, on_checkpoint=crash_at(kill_after),
                       **_PORT)
    resumed = pack_portfolio(problems[0], islands=_ISLANDS,
                             checkpoint_dir=tmp_path, checkpoint_every=2,
                             resume=True, **_PORT)
    assert _portfolio_record(resumed) == portfolio_ref


@pytest.mark.parametrize("damage", [tear_arrays, corrupt_manifest])
def test_portfolio_resume_with_corrupted_latest_checkpoint(
    problems, portfolio_ref, tmp_path, damage
):
    with pytest.raises(SimulatedCrash):
        pack_portfolio(problems[0], islands=_ISLANDS, checkpoint_dir=tmp_path,
                       checkpoint_every=2, on_checkpoint=crash_at(3), **_PORT)
    damage(latest_step_dir(tmp_path))
    resumed = pack_portfolio(problems[0], islands=_ISLANDS,
                             checkpoint_dir=tmp_path, checkpoint_every=2,
                             resume=True, **_PORT)
    assert _portfolio_record(resumed) == portfolio_ref


def test_portfolio_resume_refuses_mismatched_config(problems, tmp_path):
    with pytest.raises(SimulatedCrash):
        pack_portfolio(problems[0], islands=_ISLANDS, checkpoint_dir=tmp_path,
                       checkpoint_every=1, on_checkpoint=crash_at(1), **_PORT)
    other = [IslandSpec("ga-nfd", seed=99)] + _ISLANDS[1:]
    with pytest.raises(ValueError, match="differently-configured"):
        pack_portfolio(problems[0], islands=other, checkpoint_dir=tmp_path,
                       checkpoint_every=1, resume=True, **_PORT)


def test_single_island_portfolio_checkpoint_parity(problems, tmp_path):
    # a single-island run normally advances unbounded in ONE call; with
    # checkpointing it is segmented at synthetic barriers — trajectories
    # must not notice (the PR-5 resumable-engine contract)
    one = [IslandSpec("sa-s", seed=6)]
    kw = dict(_PORT, migration_every=0)
    ref = _portfolio_record(pack_portfolio(problems[0], islands=one, **kw))
    got = _portfolio_record(
        pack_portfolio(problems[0], islands=one, checkpoint_dir=tmp_path,
                       checkpoint_every=1, **kw)
    )
    # barrier counters differ by construction (synthetic segmentation);
    # cost/packing/iterations must not
    assert got[:3] == ref[:3]


# -------------------------------------------- wall-clock truncation surfacing
def test_portfolio_truncation_is_recorded_and_warned(problems):
    with pytest.warns(RuntimeWarning, match="wall-clock"):
        res = pack_portfolio(
            problems[0], n_islands=2, seed=1, migration_every=16,
            max_seconds=0.0, max_iterations=10**9, backend="ref",
        )
    assert res.params["truncated_by_wallclock"] is True
    assert res.params["barriers"] >= 1


def test_portfolio_budget_terminated_run_is_not_marked_truncated(
    problems, portfolio_ref
):
    # the reference fixture ran under iteration budgets with a huge wall cap
    res = pack_portfolio(problems[0], islands=_ISLANDS, **_PORT)
    assert res.params["truncated_by_wallclock"] is False
    assert _portfolio_record(res) == portfolio_ref
