"""Optimizer, data pipeline, checkpointing, fault-tolerant loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 1.0])

    @jax.jit
    def step(params, opt):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(cfg, params, g, opt)

    for _ in range(200):
        params, opt, metrics = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)
    assert int(opt["step"]) == 200


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(learning_rate=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(cfg, params, g, opt)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_cosine_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(lrs[10] - 1.0) < 0.1
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)


# --------------------------------------------------------------------- data
def test_pipeline_deterministic_and_restorable():
    cfg = DataConfig(seq_len=128, global_batch=2, vocab_size=1000, seed=3)
    p1 = SyntheticTokenPipeline(cfg)
    b1 = [p1.next_batch() for _ in range(3)]
    # restore mid-stream
    p2 = SyntheticTokenPipeline(cfg)
    p2.next_batch()
    state = p2.state()
    p3 = SyntheticTokenPipeline(cfg)
    p3.restore(state)
    b2a, b3a = p2.next_batch(), p3.next_batch()
    np.testing.assert_array_equal(b2a["tokens"], b3a["tokens"])
    # full determinism
    p4 = SyntheticTokenPipeline(cfg)
    b4 = [p4.next_batch() for _ in range(3)]
    for x, y in zip(b1, b4):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_pipeline_targets_are_next_tokens():
    cfg = DataConfig(seq_len=256, global_batch=2, vocab_size=500, seed=1)
    b = SyntheticTokenPipeline(cfg).next_batch()
    toks, tgts, segs = b["tokens"], b["targets"], b["segments"]
    for row in range(toks.shape[0]):
        for t in range(toks.shape[1] - 1):
            if tgts[row, t] >= 0 and segs[row, t] == segs[row, t + 1] != 0:
                assert tgts[row, t] == toks[row, t + 1]


def test_packing_beats_unpacked_efficiency():
    packed = DataConfig(seq_len=512, global_batch=4, seed=5, pack=True)
    unpacked = dataclasses.replace(packed, pack=False)
    bp = SyntheticTokenPipeline(packed).next_batch()
    bu = SyntheticTokenPipeline(unpacked).next_batch()
    fill_p = float((bp["segments"] > 0).mean())
    fill_u = float((bu["segments"] > 0).mean())
    assert fill_p > fill_u


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    state = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16)},
    }
    mgr.save(3, state, extra={"data": {"doc_index": 7, "step": 3}})
    step, restored, extra = mgr.restore(jax.tree.map(np.asarray, state))
    assert step == 3 and extra["data"]["doc_index"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_integrity_check(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = {"a": jnp.ones(3)}
    mgr.save(1, state)
    # corrupt
    f = tmp_path / "step_00000001" / "arrays.npz"
    f.write_bytes(f.read_bytes()[:-7] + b"garbage")
    with pytest.raises(IOError):
        mgr.restore(jax.tree.map(np.asarray, state))


def test_checkpoint_gc_keeps_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.ones(2)})
    assert mgr.all_steps() == [3, 4]


# ---------------------------------------------------------------- the loop
def test_train_loop_resume_and_nan_rollback(tmp_path):
    from repro.runtime.loop import LoopConfig, TrainLoop
    from repro.runtime.steps import TrainState

    cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=64, seed=0)
    pipeline = SyntheticTokenPipeline(cfg)
    calls = {"n": 0}

    def fake_step(state, batch):
        calls["n"] += 1
        w = state.params["w"] + 1.0
        # transient fault: exactly the 5th *invocation* produces a NaN
        # (e.g. a poisoned batch); after rollback+skip the retry is clean
        loss = jnp.asarray(np.nan if calls["n"] == 5 else 1.0 / float(w[0]))
        return TrainState({"w": w}, state.opt), {"loss": loss}

    mgr = CheckpointManager(tmp_path, async_save=False)
    loop = TrainLoop(
        fake_step, pipeline, mgr,
        LoopConfig(total_steps=8, ckpt_every=2, rollback_on_nan=True),
    )
    state = TrainState({"w": jnp.zeros(1)}, {})
    final_step, state, hist = loop.run(state, 0)
    assert final_step == 8
    assert calls["n"] > 8  # rollback caused re-execution
    # resume path
    pipeline2 = SyntheticTokenPipeline(cfg)
    loop2 = TrainLoop(fake_step, pipeline2, mgr, LoopConfig(total_steps=8))
    start, state2 = loop2.resume_or_init(TrainState({"w": jnp.zeros(1)}, {}))
    assert start == 8
