"""Service-level tests for packing-as-a-service (``repro.serve``).

The load-bearing contract, inherited from the sweep core: every response
— micro-batched, coalesced, memory-cached, or store-served — is
bit-identical to standalone ``pack(problem, seed=s)`` with the service's
solver settings.  Plus the operational semantics: in-flight duplicate
coalescing, warm restarts over a persistent store dir, the deadline
single-candidate fallback, bounded-queue backpressure, drain-on-shutdown,
and the ``stats()`` surface.  Everything runs deterministic budgets
(iteration-driven termination, wall caps out of reach) on the python
backend so results are reproducible on any host.
"""
import asyncio

import pytest

import repro.core as c
from repro.serve import (
    MicroBatcher,
    PackingService,
    Request,
    make_problems,
    result_signature,
)
from repro.serve.stats import LatencyStats

_KW = dict(backend="python", max_seconds=1e9, patience=10**9,
           max_iterations=80, n_chains=3)

PROBS = make_problems(4, seed=3, hetero=True, max_buffers=14)


def _ref(prob, seed):
    return c.pack(prob, "sa-s", seed=seed, **_KW)


def _service(**kw):
    merged = {**_KW, **kw}
    return PackingService("sa-s", **merged)


# ------------------------------------------------------------ bit parity
def test_single_request_bit_identical_to_pack():
    async def go():
        async with _service() as svc:
            return await svc.pack(PROBS[0], seed=7)

    res = asyncio.run(go())
    assert result_signature(res) == result_signature(_ref(PROBS[0], 7))


def test_microbatched_mixed_fleet_bit_parity():
    """Concurrent mixed requests ride shared micro-batches, yet every
    response equals its standalone run — batching is execution shape."""
    reqs = [(i, s) for i in range(len(PROBS)) for s in (0, 1)]

    async def go():
        async with _service(max_batch=4, max_wait_ms=20.0) as svc:
            out = await asyncio.gather(
                *(svc.pack(PROBS[i], seed=s) for i, s in reqs)
            )
            return out, svc.stats()

    out, stats = asyncio.run(go())
    for (i, s), res in zip(reqs, out):
        assert result_signature(res) == result_signature(_ref(PROBS[i], s))
    assert stats["solved"] == len(reqs)
    assert stats["batches"] < len(reqs)  # real batching happened
    assert stats["batch_occupancy"]["mean"] > 1.0


# ------------------------------------------------- dedup: coalesce + caches
def test_inflight_duplicates_coalesce_to_one_solve():
    async def go():
        async with _service() as svc:
            out = await asyncio.gather(
                *(svc.pack(PROBS[1], seed=5) for _ in range(6))
            )
            return out, svc.stats()

    out, stats = asyncio.run(go())
    assert stats["solved"] == 1
    assert stats["coalesced"] == 5
    ref_sig = result_signature(_ref(PROBS[1], 5))
    assert all(result_signature(r) == ref_sig for r in out)


def test_sequential_repeat_hits_memory_cache():
    async def go():
        async with _service() as svc:
            a = await svc.pack(PROBS[2], seed=1)
            b = await svc.pack(PROBS[2], seed=1)
            return a, b, svc.stats()

    a, b, stats = asyncio.run(go())
    assert stats["solved"] == 1 and stats["cache_hits_mem"] == 1
    assert result_signature(a) == result_signature(b)
    assert stats["hit_rate"] == 0.5


def test_store_warm_restart_bit_identical(tmp_path):
    """A restarted service over the same store dir serves prior results
    from disk — zero solver work, bit-identical answers."""
    store = tmp_path / "store"

    async def first():
        async with _service(store_dir=store) as svc:
            return await asyncio.gather(
                *(svc.pack(p, seed=2) for p in PROBS)
            )

    async def second():
        async with _service(store_dir=store) as svc:
            out = await asyncio.gather(
                *(svc.pack(p, seed=2) for p in PROBS)
            )
            return out, svc.stats()

    cold = asyncio.run(first())
    warm, stats = asyncio.run(second())
    assert stats["solved"] == 0
    assert stats["cache_hits_store"] == len(PROBS)
    for a, b in zip(cold, warm):
        assert result_signature(a) == result_signature(b)


# ------------------------------------------------- degradation + lifecycle
def test_deadline_skips_batching_window():
    """With a 10 s batching window, a 1 ms deadline request cannot wait for
    co-batchers: it flushes immediately, alone (single-candidate fallback)."""
    async def go():
        async with _service(max_wait_ms=10_000.0) as svc:
            res = await asyncio.wait_for(
                svc.pack(PROBS[0], seed=0, deadline_ms=1.0), timeout=30.0
            )
            return res, svc.stats()

    res, stats = asyncio.run(go())
    assert result_signature(res) == result_signature(_ref(PROBS[0], 0))
    assert stats["deadline_fallbacks"] == 1
    assert stats["batch_occupancy"]["counts"] == {"1": 1}


def test_backpressure_bounded_queue_still_answers_everything():
    reqs = [(i, s) for i in range(len(PROBS)) for s in range(3)]

    async def go():
        async with _service(max_queue=2, max_batch=2) as svc:
            out = await asyncio.gather(
                *(svc.pack(PROBS[i], seed=s) for i, s in reqs)
            )
            assert svc._queue.maxsize == 2
            return out

    out = asyncio.run(go())
    for (i, s), res in zip(reqs, out):
        assert result_signature(res) == result_signature(_ref(PROBS[i], s))


def test_stop_drains_accepted_work():
    async def go():
        svc = _service()
        tasks = [
            asyncio.create_task(svc.pack(PROBS[i], seed=9))
            for i in range(len(PROBS))
        ]
        await asyncio.sleep(0.01)  # let requests reach the queue
        await svc.stop()
        assert all(t.done() for t in tasks)
        out = [t.result() for t in tasks]
        with pytest.raises(RuntimeError):
            await svc.pack(PROBS[0], seed=0)  # stopped: no new admissions
        return out

    out = asyncio.run(go())
    for i, res in enumerate(out):
        assert result_signature(res) == result_signature(_ref(PROBS[i], 9))


def test_solver_error_propagates_to_clients():
    async def go():
        async with PackingService("no-such-algo", backend="python") as svc:
            with pytest.raises(Exception):
                await svc.pack(PROBS[0], seed=0)
            return svc.stats()

    stats = asyncio.run(go())
    assert stats["inflight"] == 0  # failed request cleaned up


def test_stats_surface_shape():
    async def go():
        async with _service() as svc:
            await svc.pack(PROBS[0], seed=0)
            return svc.stats()

    stats = asyncio.run(go())
    for key in ("requests", "coalesced", "cache_hits_mem",
                "cache_hits_store", "hit_rate", "solved", "batches",
                "deadline_fallbacks", "queue_depth", "pending", "inflight",
                "batch_occupancy", "latency_cached", "latency_solved"):
        assert key in stats, key
    assert stats["latency_solved"]["count"] == 1
    assert stats["latency_solved"]["p99_s"] >= stats["latency_solved"]["p50_s"] >= 0
    assert sum(
        int(v) for v in stats["batch_occupancy"]["counts"].values()
    ) == stats["batches"]


# --------------------------------------------------- micro-batcher policy
def _req(group, deadline_at=None):
    return Request(prob=None, seed=0, key=(), group=group, future=None,
                   arrival=0.0, flush_at=0.0, deadline_at=deadline_at)


def test_batcher_size_flush_is_immediate():
    b = MicroBatcher(max_batch=2, max_wait_ms=1e6)
    b.admit(_req("g"), now=0.0)
    assert b.pop_ready(0.0) == []
    b.admit(_req("g"), now=0.0)
    (batch,) = b.pop_ready(0.0)
    assert len(batch) == 2 and b.pending() == 0


def test_batcher_age_flush_and_group_separation():
    b = MicroBatcher(max_batch=8, max_wait_ms=1000.0)
    b.admit(_req("g1"), now=0.0)
    b.admit(_req("g2"), now=0.5)
    assert b.pop_ready(0.9) == []  # neither window closed
    assert b.next_flush_at() == pytest.approx(1.0)
    batches = b.pop_ready(1.0)  # g1's window closes; g2 keeps waiting
    assert [r.group for bt in batches for r in bt] == ["g1"]
    assert b.pending() == 1


def test_batcher_deadline_rush():
    b = MicroBatcher(max_batch=8, max_wait_ms=1000.0)
    b.admit(_req("g", deadline_at=0.01), now=0.0)
    (batch,) = b.pop_ready(0.0)  # due immediately, alone
    assert len(batch) == 1 and batch[0].deadline_rushed


def test_latency_stats_percentiles():
    ls = LatencyStats()
    for v in range(1, 101):
        ls.record(float(v))
    assert ls.count == 100
    assert ls.percentile(0.50) == pytest.approx(50.0, abs=1.0)
    assert ls.percentile(0.99) == pytest.approx(99.0, abs=1.0)
    assert ls.mean == pytest.approx(50.5)
