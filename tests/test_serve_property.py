"""Hypothesis property tests for packing-as-a-service.

For *arbitrary interleavings* of request arrivals — mixed problems
(hetero and homogeneous devices), duplicate fingerprints, varying seeds,
varying micro-batch limits and flush windows, staggered vs simultaneous
admission — the service must satisfy two properties:

1. **bit-parity**: every response equals standalone
   ``pack(problem, seed)`` with the service's solver settings;
2. **coalescing**: duplicate requests collapse — the solver runs exactly
   once per *unique* task, no matter how many times or in what order the
   task is requested.

Standalone references are memoized across examples (they are pure
functions of (problem, seed)), so hypothesis explores interleavings
without re-paying the solver each time.
"""
import asyncio

import pytest

pytest.importorskip("hypothesis", reason="optional dependency: hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as c
from repro.serve import PackingService, make_problems, result_signature

_KW = dict(backend="python", max_seconds=1e9, patience=10**9,
           max_iterations=40, n_chains=2)

# small mixed corpus: index 0-2 heterogeneous (OCM inventories, kind
# lanes), 3-4 homogeneous — duplicate group keys across both families
PROBS = make_problems(3, seed=21, hetero=True, max_buffers=10) + \
    make_problems(2, seed=22, hetero=False, max_buffers=10)

_REFS: dict[tuple[int, int], tuple] = {}


def _ref_signature(idx: int, seed: int) -> tuple:
    if (idx, seed) not in _REFS:
        _REFS[(idx, seed)] = result_signature(
            c.pack(PROBS[idx], "sa-s", seed=seed, **_KW)
        )
    return _REFS[(idx, seed)]


arrivals = st.lists(
    st.tuples(
        st.integers(0, len(PROBS) - 1),  # problem (duplicates likely)
        st.integers(0, 1),               # seed pool
        st.floats(0.0, 0.004),           # admission stagger (seconds)
    ),
    min_size=1,
    max_size=10,
)


@settings(max_examples=12, deadline=None)
@given(
    arrivals,
    st.integers(1, 4),                    # max_batch
    st.sampled_from([0.0, 1.0, 8.0]),     # max_wait_ms
)
def test_any_interleaving_bit_parity_and_coalescing(reqs, max_batch, wait_ms):
    async def go():
        async with PackingService(
            "sa-s", max_batch=max_batch, max_wait_ms=wait_ms, **_KW
        ) as svc:
            async def one(idx, seed, delay):
                await asyncio.sleep(delay)
                return await svc.pack(PROBS[idx], seed=seed)

            out = await asyncio.gather(
                *(one(i, s, d) for i, s, d in reqs)
            )
            return out, svc.stats()

    out, stats = asyncio.run(go())

    for (idx, seed, _), res in zip(reqs, out):
        assert result_signature(res) == _ref_signature(idx, seed)

    unique = {(i, s) for i, s, _ in reqs}
    # exactly one solve per unique task: in-flight duplicates coalesced,
    # later duplicates memory-cached — never a repeat solve
    assert stats["solved"] == len(unique)
    assert stats["requests"] == len(reqs)
    dupes = len(reqs) - len(unique)
    assert stats["coalesced"] + stats["cache_hits_mem"] == dupes
    assert stats["inflight"] == 0 and stats["pending"] == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 8), st.integers(0, 1))
def test_n_way_duplicate_burst_is_one_solve(n, seed):
    """The sharpest coalescing case: N simultaneous identical requests."""
    async def go():
        async with PackingService("sa-s", max_batch=4, **_KW) as svc:
            out = await asyncio.gather(
                *(svc.pack(PROBS[0], seed=seed) for _ in range(n))
            )
            return out, svc.stats()

    out, stats = asyncio.run(go())
    assert stats["solved"] == 1
    assert stats["coalesced"] + stats["cache_hits_mem"] == n - 1
    sig = _ref_signature(0, seed)
    assert all(result_signature(r) == sig for r in out)
