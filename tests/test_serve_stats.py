"""Unit tests for ``repro.serve.stats`` (LatencyStats / Histogram).

These accumulators back every number ``BENCH_serve.json`` publishes, but
had no direct coverage; the small-N percentile rounding was in fact wrong
(p50 of two samples returned the upper sample) — pinned here.
"""
import math
import random

import pytest

from repro.serve.stats import Histogram, LatencyStats


# ---------------------------------------------------------------- percentiles


def test_percentile_empty_returns_zero():
    s = LatencyStats()
    assert s.percentile(0.50) == 0.0
    assert s.percentile(0.99) == 0.0
    assert s.mean == 0.0
    assert s.summary() == {"count": 0, "mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0}


def test_percentile_single_sample_is_that_sample():
    s = LatencyStats()
    s.record(3.5)
    for q in (0.01, 0.50, 0.99, 1.0):
        assert s.percentile(q) == 3.5


def test_percentile_two_samples_nearest_rank():
    # nearest-rank: p50 of {1, 9} is the ceil(0.5*2)=1st sample — the LOWER
    # one.  The old round-half-up rule returned 9 here.
    s = LatencyStats()
    s.record(9.0)
    s.record(1.0)
    assert s.percentile(0.50) == 1.0
    assert s.percentile(0.99) == 9.0


def test_percentile_three_samples_nearest_rank():
    s = LatencyStats()
    for v in (30.0, 10.0, 20.0):
        s.record(v)
    assert s.percentile(0.50) == 20.0  # ceil(0.5*3)=2nd sample
    assert s.percentile(0.99) == 30.0
    assert s.percentile(1.0 / 3.0) == 10.0


def test_percentile_matches_nearest_rank_definition_exhaustively():
    # cross-check against the textbook definition for every N up to 40
    rng = random.Random(7)
    for n in range(1, 41):
        s = LatencyStats()
        vals = [rng.uniform(0.0, 100.0) for _ in range(n)]
        for v in vals:
            s.record(v)
        ordered = sorted(vals)
        for q in (0.01, 0.25, 0.50, 0.75, 0.90, 0.99):
            rank = max(1, math.ceil(q * n))  # 1-based nearest rank
            assert s.percentile(q) == ordered[rank - 1], (n, q)


def test_percentile_q_edges_clamp_in_range():
    s = LatencyStats()
    for v in (1.0, 2.0, 3.0, 4.0):
        s.record(v)
    assert s.percentile(0.0) == 1.0  # ceil(0)=0 clamps to the first sample
    assert s.percentile(1.0) == 4.0


# ---------------------------------------------------------- decimation / cap


def test_decimation_crossing_cap_halves_reservoir_and_doubles_stride():
    s = LatencyStats(cap=8)
    for i in range(8):
        s.record(float(i))
    assert s._stride == 1 and len(s._sorted) == 8
    # the 9th sample crosses the cap: reservoir halves, stride doubles,
    # and the new sample still lands in the (now coarser) reservoir
    s.record(100.0)
    assert s._stride == 2
    assert len(s._sorted) == 5  # 8 -> every other (4) + the new sample
    assert 100.0 in s._sorted
    assert s._sorted == sorted(s._sorted)


def test_decimation_keeps_exact_count_and_mean():
    # count/mean/total are exact regardless of reservoir decimation
    s = LatencyStats(cap=4)
    vals = [float(i) for i in range(1, 101)]
    for v in vals:
        s.record(v)
    assert s.count == 100
    assert s.total == pytest.approx(sum(vals))
    assert s.mean == pytest.approx(sum(vals) / 100)
    assert len(s._sorted) <= s.cap
    assert s._stride > 1


def test_decimation_reservoir_stays_sorted_and_spans_eras():
    # after several cap crossings the retained samples still cover both the
    # oldest and the newest eras (decimation, not tail-dropping)
    s = LatencyStats(cap=16)
    for i in range(1000):
        s.record(float(i))
    assert s._sorted == sorted(s._sorted)
    assert len(s._sorted) <= s.cap
    assert min(s._sorted) < 250.0 and max(s._sorted) > 750.0
    # percentiles remain monotone in q on the decimated reservoir
    ps = [s.percentile(q) for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.99)]
    assert ps == sorted(ps)


def test_stride_skips_between_retained_samples():
    s = LatencyStats(cap=2)
    for i in range(12):
        s.record(float(i))
    # stride grew past 1, so the reservoir holds far fewer than count
    assert s._stride >= 2
    assert len(s._sorted) < s.count


# ------------------------------------------------------------------ histogram


def test_histogram_counts_mean_and_summary():
    h = Histogram()
    assert h.total == 0 and h.mean == 0.0
    for v in (3, 1, 3, 2, 3):
        h.record(v)
    assert h.total == 5
    assert h.counts == {1: 1, 2: 1, 3: 3}
    assert h.mean == pytest.approx((1 + 2 + 3 * 3) / 5)
    summ = h.summary()
    assert summ["counts"] == {"1": 1, "2": 1, "3": 3}
    assert list(summ["counts"]) == ["1", "2", "3"]  # sorted keys


def test_histogram_coerces_to_int():
    h = Histogram()
    h.record(2.0)
    h.record(2)
    assert h.counts == {2: 2}
