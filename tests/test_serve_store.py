"""Fault-injection tests for the service's persistent ``ResultStore``.

Reuses the ``tests/faultinject.py`` disk corruptors unchanged — a store
entry dir has the same ``{arrays.npz, manifest.json}`` layout as a
checkpoint step, so the same torn/corrupted/half-deleted damage applies.
The contract under damage mirrors ``restore_latest_valid``: a damaged
entry is **skipped with a logged warning and never served**; the caller
recomputes and the recompute's ``put`` repairs the entry on disk.  The
concurrent-writer contract is the atomic-rename one: a losing writer
never touches the winning entry, not even transiently.
"""
import json

import pytest

import repro.core as c
from faultinject import (
    corrupt_arrays,
    corrupt_manifest,
    half_delete,
    tear_arrays,
)
from repro.core.dse import task_key
from repro.serve import ResultStore, make_problems, result_signature

_KW = dict(backend="python", max_seconds=1e9, patience=10**9,
           max_iterations=60, n_chains=2)

PROB = make_problems(1, seed=11, hetero=True, max_buffers=12)[0]


def _solve(seed=0):
    return c.pack(PROB, "sa-s", seed=seed, **_KW)


def _key(seed=0):
    return task_key(PROB, "sa-s", seed, backend="python",
                    max_seconds=1e9,
                    hyper=dict(patience=10**9, max_iterations=60, n_chains=2))


def test_round_trip_bit_identical(tmp_path):
    store = ResultStore(tmp_path, memory_cache=False)
    res = _solve()
    assert store.put(_key(), res)
    assert _key() in store and len(store) == 1
    got = store.get(_key(), PROB)
    assert result_signature(got) == result_signature(res)
    # full metadata survives too, not just the packing
    assert got.algorithm == res.algorithm
    assert got.iterations == res.iterations
    assert got.params == res.params


def test_fresh_store_over_same_dir_serves_warm(tmp_path):
    """The killed-server model: writer process gone, a brand-new store over
    the same dir serves its results from disk."""
    ResultStore(tmp_path).put(_key(), _solve())
    reborn = ResultStore(tmp_path, memory_cache=False)
    got = reborn.get(_key(), PROB)
    assert result_signature(got) == result_signature(_solve())
    assert reborn.hits == 1 and reborn.corrupt_skipped == 0


@pytest.mark.parametrize(
    "corruptor", [tear_arrays, corrupt_arrays, corrupt_manifest, half_delete]
)
def test_damaged_entry_skipped_then_repaired(tmp_path, corruptor, caplog):
    store = ResultStore(tmp_path, memory_cache=False)
    res = _solve()
    store.put(_key(), res)
    corruptor(store.path_for(_key()))

    with caplog.at_level("WARNING", logger="repro.serve.store"):
        assert store.get(_key(), PROB) is None  # never served damaged
    assert store.corrupt_skipped == 1
    assert any("corrupt" in r.message for r in caplog.records)

    # the recompute path: put() swaps the damaged entry for a fresh one
    assert store.put(_key(), res)
    store2 = ResultStore(tmp_path, memory_cache=False)
    assert result_signature(store2.get(_key(), PROB)) == result_signature(res)


def test_wrong_key_digest_never_served(tmp_path):
    """An entry renamed over another task's slot fails the digest check."""
    store = ResultStore(tmp_path, memory_cache=False)
    store.put(_key(0), _solve(0))
    path0 = store.path_for(_key(0))
    path1 = store.path_for(_key(1))
    path0.rename(path1)  # files intact, identity wrong
    assert store.get(_key(1), PROB) is None
    assert store.corrupt_skipped == 1


def test_concurrent_second_writer_never_corrupts(tmp_path):
    """Atomic-rename contract: a losing writer leaves the winner untouched
    (same bytes before and after) and reports the lost race."""
    store_a = ResultStore(tmp_path, memory_cache=False)
    store_b = ResultStore(tmp_path, memory_cache=False)
    res = _solve()
    assert store_a.put(_key(), res)
    entry = store_a.path_for(_key())
    before = {
        f.name: f.read_bytes() for f in entry.iterdir() if f.is_file()
    }

    assert store_b.put(_key(), res) is False  # lost the race
    assert store_b.lost_races == 1
    after = {
        f.name: f.read_bytes() for f in entry.iterdir() if f.is_file()
    }
    assert after == before  # bit-for-bit untouched
    assert not list(tmp_path.glob("*.tmp*"))  # scratch dirs cleaned up

    got = store_b.get(_key(), PROB)
    assert result_signature(got) == result_signature(res)


def test_torn_tmp_dir_is_invisible(tmp_path):
    """A crash mid-write leaves only a scratch dir: not an entry, not
    counted, not served."""
    store = ResultStore(tmp_path, memory_cache=False)
    junk = tmp_path / f"entry_deadbeef.tmp-999-aa"
    junk.mkdir()
    (junk / "arrays.npz").write_bytes(b"partial")
    assert len(store) == 0
    assert store.digests() == []


def test_manifest_is_valid_json_with_sha(tmp_path):
    """Entry layout contract: manifest carries format, task digest, and the
    sha256 the corruptors/readers verify against."""
    store = ResultStore(tmp_path, memory_cache=False)
    store.put(_key(), _solve())
    manifest = json.loads(
        (store.path_for(_key()) / "manifest.json").read_text()
    )
    assert manifest["format"] == 1
    assert manifest["digest"] in store.path_for(_key()).name
    assert len(manifest["sha256"]) == 64
    assert "wall_time_s" in manifest["result"]
