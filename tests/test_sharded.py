"""Mesh-sharded fleet execution (PR 8): parity + resume-across-shard-counts.

Two sharding mechanisms, both pure execution-shape knobs:

* ``mesh=`` — the batched kernels row-shard every step over a 1-D
  ``("prob",)`` device mesh via ``shard_map`` (exact integer arithmetic, so
  bit-identical by construction).  Kernel- and engine-level mesh parity
  tests need >= 2 devices and skip otherwise; the CI sharded-smoke lane
  runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
* ``n_shards=`` — ``pack_sweep`` / ``pack_portfolio`` split each batched
  group into contiguous sub-fleets advanced concurrently on threads.
  Bit-parity holds because per-problem trajectories are fleet-composition-
  independent (each live problem consumes only its own RNG stream; frozen
  problems never draw) — these tests run on any host.

Checkpoints are cut in a canonical merged layout identical to the
unsharded snapshot, so a crashed sharded run must resume bit-identically
at ANY other shard count — pinned here with the ``tests/faultinject.py``
crash harness, both directions, for sweeps and portfolios
(docs/DESIGN.md section 14).
"""
from __future__ import annotations

import numpy as np
import pytest

import repro.core as c
from repro.core import pack_portfolio, pack_sweep
from repro.core.dse import shard_chunks
from repro.core.problem import (
    BRAM18,
    URAM288,
    Buffer,
    OCMInventory,
    PackingProblem,
)

from faultinject import SimulatedCrash, crash_at


def _n_devices() -> int:
    import jax

    return len(jax.devices())


def _mesh_or_skip(k: int):
    if _n_devices() < k:
        pytest.skip(f"needs {k} devices (CI sharded lane forces 8)")
    from repro.launch.mesh import make_sweep_mesh

    return make_sweep_mesh(k)


def _problem(seed: int, hetero: bool = False) -> PackingProblem:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 30))
    bufs = [
        Buffer(width=int(rng.integers(1, 80)), depth=int(rng.integers(1, 40_000)),
               layer=int(rng.integers(0, 5)))
        for _ in range(n)
    ]
    ocm = (
        OCMInventory((BRAM18, URAM288), (n * 3, 8), name=f"dev{seed}")
        if hetero else None
    )
    return PackingProblem(bufs, max_items=4, name=f"sh{seed}", ocm=ocm)


def _record(sw) -> list[tuple]:
    return [
        (r.cost, r.solution.state_dict(), r.iterations,
         [cc for _, cc in r.trace])
        for r in sw.results
    ]


_KW = dict(max_seconds=1e9, patience=10**9)
_SA = dict(_KW, backend="python", max_iterations=400, n_chains=4)
_GA = dict(_KW, backend="ref", max_generations=8, n_pop=10)


# ------------------------------------------------------------- shard chunking
def test_shard_chunks_contiguous_and_balanced():
    assert shard_chunks(7, 3) == [[0, 1, 2], [3, 4], [5, 6]]
    assert shard_chunks(4, 8) == [[0], [1], [2], [3]]  # capped at n
    assert shard_chunks(5, 1) == [[0, 1, 2, 3, 4]]
    for n, k in ((13, 4), (8, 8), (9, 2)):
        chunks = shard_chunks(n, k)
        assert [j for ch in chunks for j in ch] == list(range(n))
        sizes = [len(ch) for ch in chunks]
        assert max(sizes) - min(sizes) <= 1


def test_make_sweep_mesh_validation():
    from repro.launch.mesh import make_sweep_mesh

    with pytest.raises(ValueError):
        make_sweep_mesh(0)
    with pytest.raises(RuntimeError, match="host_platform_device_count"):
        make_sweep_mesh(_n_devices() + 1)
    mesh = make_sweep_mesh(1)
    assert mesh.axis_names == ("prob",) and mesh.shape["prob"] == 1


# ------------------------------------------------------- kernel mesh parity
def test_kernel_mesh_parity():
    mesh = _mesh_or_skip(2)
    from repro.kernels.binpack_fitness.ops import population_costs
    from repro.kernels.binpack_sa_step.ops import sa_step_deltas

    rng = np.random.default_rng(0)
    W = rng.integers(0, 40, size=(7, 6))
    H = rng.integers(0, 9000, size=(7, 6))
    base = np.asarray(population_costs(W, H, backend="ref"))
    shrd = np.asarray(population_costs(W, H, backend="ref", mesh=mesh))
    np.testing.assert_array_equal(base, shrd)

    ow = rng.integers(0, 40, size=(5, 3))
    oh = rng.integers(0, 9000, size=(5, 3))
    nw = rng.integers(0, 40, size=(5, 3))
    nh = rng.integers(0, 9000, size=(5, 3))
    d0 = sa_step_deltas(ow, oh, nw, nh, backend="ref")
    d1 = sa_step_deltas(ow, oh, nw, nh, backend="ref", mesh=mesh)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_portfolio_step_kernel_mesh_parity():
    mesh = _mesh_or_skip(2)
    from repro.kernels.binpack_portfolio_step.ops import portfolio_step

    rng = np.random.default_rng(1)
    W = rng.integers(0, 40, size=(3, 8, 5))
    H = rng.integers(0, 9000, size=(3, 8, 5))
    ow = rng.integers(0, 40, size=(6, 2))
    oh = rng.integers(0, 9000, size=(6, 2))
    nw = rng.integers(0, 40, size=(6, 2))
    nh = rng.integers(0, 9000, size=(6, 2))
    t0, d0 = portfolio_step(W, H, ow, oh, nw, nh, backend="ref")
    t1, d1 = portfolio_step(W, H, ow, oh, nw, nh, backend="ref", mesh=mesh)
    np.testing.assert_array_equal(t0, t1)
    np.testing.assert_array_equal(d0, d1)


# --------------------------------------------------- sweep n_shards parity
@pytest.mark.parametrize("n_shards", [2, 3, 8])
def test_sweep_sa_n_shards_bit_identical(n_shards):
    probs = [_problem(s) for s in (11, 12, 13, 14, 15)]
    base = pack_sweep(probs, "sa-s", seed=3, **_SA)
    shrd = pack_sweep(probs, "sa-s", seed=3, n_shards=n_shards, **_SA)
    assert _record(shrd) == _record(base)
    assert shrd.params["n_shards"] == n_shards


def test_sweep_sa_n_shards_hetero_bit_identical():
    probs = [_problem(s, hetero=True) for s in (21, 22, 23)]
    base = pack_sweep(probs, "sa-s", seed=1, **_SA)
    shrd = pack_sweep(probs, "sa-s", seed=1, n_shards=3, **_SA)
    assert _record(shrd) == _record(base)


def test_sweep_ga_n_shards_bit_identical():
    probs = [_problem(s) for s in (31, 32, 33, 34)]
    base = pack_sweep(probs, "ga-nfd", seed=2, **_GA)
    shrd = pack_sweep(probs, "ga-nfd", seed=2, n_shards=3, **_GA)
    assert _record(shrd) == _record(base)


def test_sweep_n_shards_validation():
    with pytest.raises(ValueError, match="n_shards"):
        pack_sweep([_problem(1)], "sa-s", n_shards=0, **_SA)


# ------------------------------------------------------- sweep mesh parity
def test_sweep_sa_mesh_bit_identical():
    mesh = _mesh_or_skip(2)
    probs = [_problem(s) for s in (41, 42, 43)]
    kw = dict(_KW, backend="ref", max_iterations=100, n_chains=3)
    base = pack_sweep(probs, "sa-s", seed=5, **kw)
    shrd = pack_sweep(probs, "sa-s", seed=5, mesh=mesh, **kw)
    assert _record(shrd) == _record(base)
    # mesh + n_shards > 1: sub-fleets pinned round-robin to the devices
    pinned = pack_sweep(probs, "sa-s", seed=5, mesh=mesh, n_shards=2, **kw)
    assert _record(pinned) == _record(base)


def test_sweep_ga_mesh_bit_identical():
    mesh = _mesh_or_skip(2)
    probs = [_problem(s) for s in (51, 52, 53)]
    kw = dict(_GA, max_generations=6)
    base = pack_sweep(probs, "ga-nfd", seed=4, **kw)
    shrd = pack_sweep(probs, "ga-nfd", seed=4, mesh=mesh, **kw)
    assert _record(shrd) == _record(base)


# --------------------------------------------------------- portfolio parity
_PF = dict(
    _KW, max_iterations=384, max_generations=6, n_pop=10, backend="python",
    sa_chains=4,
)


def _pf_record(res) -> tuple:
    return (res.cost, res.solution.state_dict(), res.iterations,
            res.params["barriers"], res.params["migrations"])


@pytest.mark.parametrize("n_shards", [2, 5])
def test_portfolio_n_shards_bit_identical(n_shards):
    prob = _problem(61)
    kw = dict(_PF, n_islands=5, algorithms=("sa-s",), seed=3)
    base = pack_portfolio(prob, **kw)
    shrd = pack_portfolio(prob, n_shards=n_shards, **kw)
    assert _pf_record(shrd) == _pf_record(base)
    assert shrd.params["n_shards"] == n_shards


def test_portfolio_mixed_lineup_n_shards_bit_identical():
    prob = _problem(62)
    kw = dict(_PF, n_islands=4, seed=0)  # ga-nfd + sa-s + sa-nfd + ga-nfd
    base = pack_portfolio(prob, **kw)
    shrd = pack_portfolio(prob, n_shards=2, **kw)
    assert _pf_record(shrd) == _pf_record(base)


def test_portfolio_mesh_bit_identical_and_fuse_needs_one_shard():
    mesh = _mesh_or_skip(2)
    prob = _problem(63)
    kw = dict(
        _KW, max_iterations=128, max_generations=5, n_pop=10, backend="ref",
        sa_chains=4, n_islands=4, algorithms=("sa-s", "ga-nfd"), seed=3,
    )
    base = pack_portfolio(prob, **kw)
    shrd = pack_portfolio(prob, mesh=mesh, **kw)
    assert _pf_record(shrd) == _pf_record(base)
    # one fleet shard keeps fused dispatch on; splitting the fleet turns it
    # off (the fused kernel needs the whole fleet in one block state) while
    # staying bit-identical
    assert shrd.params["fused"] == base.params["fused"]
    split = pack_portfolio(prob, mesh=mesh, n_shards=2, **kw)
    assert _pf_record(split) == _pf_record(base)
    assert split.params["fused"] is False


# ---------------------------------------- resume across shard counts (PR 8)
@pytest.mark.parametrize("save_shards,resume_shards", [(4, 1), (1, 4), (3, 2)])
def test_sweep_resume_across_shard_counts(tmp_path, save_shards, resume_shards):
    probs = [_problem(s) for s in (71, 72, 73, 74, 75)]
    kw = dict(_SA, max_iterations=600)
    base = _record(pack_sweep(probs, "sa-s", seed=3, **kw))
    d = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        pack_sweep(probs, "sa-s", seed=3, checkpoint_dir=d,
                   checkpoint_every=128, n_shards=save_shards,
                   on_checkpoint=crash_at(2), **kw)
    out = pack_sweep(probs, "sa-s", seed=3, checkpoint_dir=d,
                     checkpoint_every=128, n_shards=resume_shards,
                     resume=True, **kw)
    assert _record(out) == base


@pytest.mark.parametrize("save_shards,resume_shards", [(4, 1), (1, 4)])
def test_portfolio_resume_across_shard_counts(tmp_path, save_shards,
                                              resume_shards):
    prob = _problem(81)
    kw = dict(_PF, max_iterations=512, n_islands=5, algorithms=("sa-s",),
              seed=3)
    base = _pf_record(pack_portfolio(prob, **kw))
    d = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        pack_portfolio(prob, checkpoint_dir=d, checkpoint_every=2,
                       n_shards=save_shards, on_checkpoint=crash_at(2), **kw)
    out = pack_portfolio(prob, checkpoint_dir=d, checkpoint_every=2,
                         n_shards=resume_shards, resume=True, **kw)
    assert _pf_record(out) == base


def test_sweep_sharded_checkpoint_matches_unsharded_layout(tmp_path):
    """A snapshot cut by a sharded sweep restores into an UNsharded resume
    and vice versa because both use one canonical merged layout — also
    covered above; this pins the single-shard merge == encode equivalence
    used for backward compatibility with PR-6 snapshots."""
    from repro.core.resume import encode_block_state, merge_block_states
    from repro.core.api import make_packer

    probs = [_problem(s) for s in (91, 92, 93)]
    packer = make_packer("sa-s", seed=0, max_seconds=1e9, patience=10**9,
                         max_iterations=64, n_chains=4, backend="python")
    packer._hetero = False
    rngs = [np.random.default_rng(s) for s in (1, 2, 3)]
    st = packer._block_start(probs, rngs, [[], [], []], "python")
    packer._block_run(st, 64)
    a0, e0 = encode_block_state(st)
    a1, e1 = merge_block_states([st])
    assert set(a0) == set(a1)
    for k in a0:
        np.testing.assert_array_equal(a0[k], a1[k])
    assert {k: v for k, v in e0.items() if k not in ("rngs", "traces")} == \
           {k: v for k, v in e1.items() if k not in ("rngs", "traces")}
    assert e0["rngs"] == e1["rngs"] and e0["traces"] == e1["traces"]
