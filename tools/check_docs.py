#!/usr/bin/env python
"""Docs integrity checker: fail CI on broken references in the markdown.

Scans ``README.md`` and ``docs/*.md`` for three kinds of references and
verifies each against the working tree:

1. Relative markdown links ``[text](path)`` (external schemes and pure
   ``#anchor`` links are skipped; a ``path#anchor`` has its anchor
   stripped) — the target file or directory must exist.
2. Backticked repo paths — any `` `a/b.ext` `` with a known source/doc
   extension — must exist.  Paths under gitignored output directories
   (``benchmarks/out/``) are exempt: they name artifacts benchmarks
   produce, not tracked files.
3. Backticked dotted module references starting with ``repro.`` — the
   longest importable prefix must resolve to a module file or package
   under ``src/`` (trailing attribute/function parts are allowed, e.g.
   ``repro.core.api.pack``).

Run from the repository root (CI does):

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CHECKED_EXTS = (".py", ".md", ".yml", ".yaml", ".txt", ".toml", ".ini", ".csv")
OUTPUT_DIRS = ("benchmarks/out/",)

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICKED = re.compile(r"`([^`\n]+)`")
MODULE_REF = re.compile(r"^repro(\.\w+)+$")


def _module_resolves(ref: str) -> bool:
    parts = ref.split(".")
    # longest prefix that is a module/package wins; tails are attributes
    for k in range(len(parts), 1, -1):
        base = ROOT / "src" / Path(*parts[:k])
        if base.with_suffix(".py").is_file() or (base / "__init__.py").is_file():
            return True
    return False


def check_file(md: Path) -> list[str]:
    errors: list[str] = []
    text = md.read_text()
    for lineno, line in enumerate(text.splitlines(), 1):
        for target in MD_LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists() and not (ROOT / rel).exists():
                errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                              f"broken link target {target!r}")
        for ref in TICKED.findall(line):
            ref = ref.strip()
            if MODULE_REF.match(ref):
                if not _module_resolves(ref):
                    errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                                  f"unresolvable module reference {ref!r}")
                continue
            if "/" in ref and ref.endswith(CHECKED_EXTS) and " " not in ref:
                if any(ref.startswith(d) for d in OUTPUT_DIRS):
                    continue
                if not (ROOT / ref).exists():
                    errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                                  f"missing repo path {ref!r}")
    return errors


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    errors: list[str] = []
    n_refs = 0
    for md in files:
        if md.exists():
            errors.extend(check_file(md))
            n_refs += 1
    if errors:
        print(f"docs check FAILED ({len(errors)} broken reference(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs check OK ({n_refs} file(s) scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
