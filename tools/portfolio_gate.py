#!/usr/bin/env python
"""CI portfolio-throughput smoke gate (ISSUE 7 satellite).

Times the MIXED island lineup — the one the serial barrier loop lost to
the legacy thread pool by 4x — on a tiny wall budget, fleet-native
`pack_portfolio` vs the `pack_portfolio_threads` baseline, and fails if
the fleet's aggregate iteration throughput drops below a soft threshold
of the baseline's:

    python tools/portfolio_gate.py                 # defaults: 0.7x @ 1.5s
    python tools/portfolio_gate.py --threshold 0.9 --budget 3.0

The threshold is deliberately SOFT (0.7x, not the >= 1.0x the real bench
shows on 12s budgets): a 1-2 second CI budget on a loaded shared runner
is noisy, and this lane exists to catch the pathological regression —
the serial-loop 0.24x cliff — not to benchmark.  Quality is asserted
only as a sanity bound (the fleet must beat the singleton baseline);
cost-vs-threads comparisons at CI budgets are pure noise.

The gate also runs a racing smoke (``--skip-racing`` to disable): a
tiny deterministic ``pack_portfolio(auto=True)`` race, run twice, must
be bit-identical (cost/iterations/eliminations) and must respect its
ledger — the machine-independent half of the self-tuning deliverable
(docs/DESIGN.md section 16).

Set ``PORTFOLIO_GATE_SKIP=1`` to skip the gate entirely (e.g. on
known-oversubscribed runners); it exits 0 without running anything.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MIXED = ("ga-nfd", "sa-s", "sa-nfd")


def _throughput(res) -> float:
    return res.iterations / max(res.wall_time_s, 1e-9)


def _racing_smoke(c, prob, seed: int) -> int:
    """Deterministic auto-race gate: bit-equal double run, ledger respected."""
    kw = dict(
        auto=True, seed=seed, backend="python", max_seconds=1e9,
        patience=10**9, migration_every=32, race_budget=4096,
        race_grid=[
            ("sa-s", {"n_chains": 4}),
            ("sa-s", {"n_chains": 4, "ladder_max": 8.0}),
            ("ga-nfd", {"n_pop": 10}),
            ("sa-nfd", {}),
        ],
    )

    def record(res):
        race = res.params["race"]
        return (res.cost, res.iterations, res.solution.state_dict(),
                race["spent"], tuple(race["survivors"]),
                tuple((e["island"], e["barrier"]) for e in race["eliminated"]))

    a, b = record(c.pack_portfolio(prob, **kw)), record(c.pack_portfolio(prob, **kw))
    race_ok = a == b and 0 < a[3] <= 4096
    print(f"  racing  : cost {a[0]}  spent {a[3]}/4096  "
          f"survivors {list(a[4])}  bit-equal {a == b}")
    if not race_ok:
        print("FAIL: racing smoke — run-to-run mismatch or ledger overdraw "
              "(pack_portfolio(auto=True) determinism has regressed)")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--accelerator", default="CNV-W1A1")
    ap.add_argument("--budget", type=float, default=1.5,
                    help="wall seconds per engine (default 1.5)")
    ap.add_argument("--threshold", type=float, default=0.7,
                    help="min fleet/threads throughput ratio (default 0.7)")
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-racing", action="store_true",
                    help="skip the deterministic auto-race smoke")
    args = ap.parse_args(argv)

    if os.environ.get("PORTFOLIO_GATE_SKIP") == "1":
        print("portfolio gate: skipped (PORTFOLIO_GATE_SKIP=1)")
        return 0

    import warnings

    import repro.core as c
    from repro.core.portfolio import pack_portfolio_threads

    prob = c.get_problem(args.accelerator)
    hp = c.hyperparams(args.accelerator)
    kw = dict(n_islands=args.islands, algorithms=MIXED, seed=args.seed,
              max_seconds=args.budget, sa_chains=8, **hp)
    with warnings.catch_warnings():
        # wall-budgeted on purpose: the truncation RuntimeWarning is expected
        warnings.simplefilter("ignore", RuntimeWarning)
        rt = pack_portfolio_threads(prob, **kw)
        rf = c.pack_portfolio(prob, **kw)
    tput_t, tput_f = _throughput(rt), _throughput(rf)
    ratio = tput_f / max(tput_t, 1e-9)
    singleton = prob.singleton_solution().cost()
    print(f"portfolio gate [{args.accelerator} mixed x{args.islands} "
          f"@{args.budget}s]:")
    print(f"  threads : {rt.iterations:>9d} iters  {tput_t:>10.0f}/s  "
          f"cost {rt.cost}")
    print(f"  fleet   : {rf.iterations:>9d} iters  {tput_f:>10.0f}/s  "
          f"cost {rf.cost}  (scheduler={rf.params['scheduler']}, "
          f"fused={rf.params['fused']})")
    print(f"  ratio   : {ratio:.2f}x  (soft threshold {args.threshold:.2f}x)")
    if rf.cost >= singleton:
        print(f"FAIL: fleet cost {rf.cost} did not beat the singleton "
              f"baseline {singleton}")
        return 1
    if ratio < args.threshold:
        print(f"FAIL: fleet throughput {ratio:.2f}x threads is below the "
              f"{args.threshold:.2f}x gate — the concurrent barrier "
              "scheduler has regressed (see docs/DESIGN.md section 13)")
        return 1
    if not args.skip_racing and _racing_smoke(c, prob, args.seed):
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
