#!/usr/bin/env python
"""Synthetic traffic driver for the packing service — CLI + CI kill lane.

Drives an in-process :class:`repro.serve.PackingService` with a seeded
Poisson/Zipf workload (see ``repro.serve.traffic``), optionally SIGKILLs
itself mid-run, and verifies warm-restart behavior over a persistent
store dir:

    # cold run against a fresh store, then die hard after 8 responses
    python tools/serve_traffic.py --store /tmp/pack_store --smoke --die-after 8

    # restart over the same store: prior results MUST be served warm and
    # every response MUST bit-match standalone pack()
    python tools/serve_traffic.py --store /tmp/pack_store --smoke \
        --expect-warm --verify --out /tmp/serve.json

    # a third pass is fully warm: no solver work at all
    python tools/serve_traffic.py --store /tmp/pack_store --smoke \
        --expect-no-solves --verify

The workload is pure function of ``--seed``/``--requests``/``--problems``,
so every invocation above replays identical traffic — which is what makes
"restart serves prior results bit-identically" a checkable claim.  Exit
code is non-zero on any failed expectation; ``--die-after`` exits via
SIGKILL (shell reports 137), the honest crash the store must survive.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import signal
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# deterministic engines: iteration budgets drive termination, the wall cap
# and patience are parked out of reach (DESIGN.md section 12)
_HUGE_SECONDS = 1e9
_HUGE_PATIENCE = 10**9


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", required=True, help="persistent store dir")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload + budgets (CI-scale)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--problems", type=int, default=None,
                    help="corpus size (Zipf popularity ranks)")
    ap.add_argument("--rate-hz", type=float, default=500.0,
                    help="Poisson arrival rate")
    ap.add_argument("--zipf-a", type=float, default=1.2)
    ap.add_argument("--concurrency", type=int, default=16,
                    help="max in-flight clients")
    ap.add_argument("--n-seeds", type=int, default=2,
                    help="per-request seed pool size")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + corpus RNG seed")
    ap.add_argument("--hetero", action="store_true",
                    help="heterogeneous corpus (OCM inventories)")
    ap.add_argument("--algorithm", default="sa-s")
    ap.add_argument("--backend", default="python")
    ap.add_argument("--max-iterations", type=int, default=None)
    ap.add_argument("--n-chains", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="give every --deadline-every'th request a deadline")
    ap.add_argument("--deadline-every", type=int, default=0)
    ap.add_argument("--die-after", type=int, default=0, metavar="K",
                    help="SIGKILL this process after K responses "
                         "(0 = run to completion)")
    ap.add_argument("--expect-warm", action="store_true",
                    help="fail unless >=1 response came from the store")
    ap.add_argument("--expect-no-solves", action="store_true",
                    help="fail unless zero solver work ran (fully warm)")
    ap.add_argument("--verify", action="store_true",
                    help="bit-compare every unique task against "
                         "standalone pack()")
    ap.add_argument("--out", default=None, help="write JSON record here")
    args = ap.parse_args(argv)

    n_requests = args.requests or (24 if args.smoke else 200)
    n_problems = args.problems or (4 if args.smoke else 12)
    max_iterations = args.max_iterations or (60 if args.smoke else 250)

    from repro.serve import (
        PackingService,
        make_problems,
        make_workload,
        run_traffic,
        verify_parity,
    )

    problems = make_problems(n_problems, seed=args.seed, hetero=args.hetero)
    workload = make_workload(
        n_requests, n_problems, rate_hz=args.rate_hz, zipf_a=args.zipf_a,
        n_seeds=args.n_seeds, seed=args.seed,
    )

    on_response = None
    if args.die_after:
        served = [0]

        def on_response(rec):
            served[0] += 1
            if served[0] >= args.die_after:
                os.kill(os.getpid(), signal.SIGKILL)

    async def drive():
        async with PackingService(
            args.algorithm,
            store_dir=args.store,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            max_queue=max(args.concurrency, 16),
            backend=args.backend,
            max_seconds=_HUGE_SECONDS,
            patience=_HUGE_PATIENCE,
            max_iterations=max_iterations,
            n_chains=args.n_chains,
        ) as svc:
            out = await run_traffic(
                svc, problems, workload,
                concurrency=args.concurrency,
                deadline_ms=args.deadline_ms,
                deadline_every=args.deadline_every,
                on_response=on_response,
            )
            stats = svc.stats()
            parity = (
                verify_parity(svc, problems, workload) if args.verify
                else None
            )
            return out, stats, parity

    out, stats, parity = asyncio.run(drive())

    record = {
        "requests": n_requests,
        "problems": n_problems,
        "rps": out["rps"],
        "latency": out["latency"],
        "stats": stats,
        "parity": parity,
    }
    print(json.dumps({k: record[k] for k in ("rps", "latency")}, indent=2))
    print(f"served {stats['requests']} requests: {stats['solved']} solved, "
          f"{stats['cache_hits_store']} store hits, "
          f"{stats['cache_hits_mem']} memory hits, "
          f"{stats['coalesced']} coalesced")
    if args.out:
        Path(args.out).write_text(json.dumps(record, indent=2))

    failures = []
    if args.expect_warm and stats["cache_hits_store"] < 1:
        failures.append("expected >=1 store hit, got 0")
    if args.expect_no_solves and stats["solved"] != 0:
        failures.append(f"expected 0 solves, got {stats['solved']}")
    if parity is not None and not parity["parity"]:
        failures.append(f"bit-parity FAILED: {parity['mismatches']}")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if not failures and args.verify:
        print(f"parity OK over {parity['tasks']} unique tasks")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
