#!/usr/bin/env python
"""Preemptible sweep/portfolio driver with crash injection — the CLI half of
the fault-injection harness (the in-process half lives in tests/faultinject.py).

Run a checkpointed sweep or portfolio over Table-1 problems, optionally
SIGKILL the process right after the Nth durable snapshot, then resume and
compare against an uninterrupted reference:

    # reference (uninterrupted) run
    python tools/sweep_resume.py --mode sweep --problems CNV-W1A1,CNV-W2A2 \
        --dir /tmp/ref_ck --out /tmp/ref.json

    # crashed run: a real SIGKILL after checkpoint 2 (exit code -9)
    python tools/sweep_resume.py --mode sweep --problems CNV-W1A1,CNV-W2A2 \
        --dir /tmp/ck --die-at-checkpoint 2

    # resume from the newest intact snapshot, then diff the parity records
    python tools/sweep_resume.py --mode sweep --problems CNV-W1A1,CNV-W2A2 \
        --dir /tmp/ck --resume --out /tmp/resumed.json
    python - /tmp/ref.json /tmp/resumed.json <<'PY'
    import json, sys
    a, b = (json.load(open(p)) for p in sys.argv[1:3])
    assert a == b, "resumed run is not bit-identical to the reference"
    PY

The parity record holds everything the bit-exact restart contract covers —
final best cost, packing (bins + kind lanes), iteration counts, and (for
sweeps) per-candidate improvement-trace cost sequences.  Wall-clock values
(and the portfolio's wall-time-ordered merged trace) are exempt and never
recorded; see docs/DESIGN.md section 12.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# deterministic engines: iteration budgets drive termination, the wall cap
# and patience are parked out of reach (DESIGN.md section 12)
_HUGE_SECONDS = 1e9
_HUGE_PATIENCE = 10**9


def _die_at(n: int):
    """SIGKILL ourselves right after the Nth durable checkpoint write."""

    def hook(step: int) -> None:
        if step >= n:
            os.kill(os.getpid(), signal.SIGKILL)

    return hook


def _solution_record(res) -> dict:
    return {
        "cost": int(res.cost),
        "bins": [[int(i) for i in b] for b in res.solution.bins],
        "kinds": [int(k) for k in res.solution.kinds],
        "iterations": int(res.iterations),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", choices=("sweep", "portfolio"), default="sweep")
    ap.add_argument("--problems", default="CNV-W1A1,CNV-W2A2",
                    help="comma-separated Table-1 problem names "
                         "(portfolio mode uses the first)")
    ap.add_argument("--dir", required=True, help="checkpoint directory")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest intact checkpoint")
    ap.add_argument("--die-at-checkpoint", type=int, default=0, metavar="N",
                    help="SIGKILL this process right after the Nth "
                         "checkpoint write (0 = run to completion)")
    ap.add_argument("--out", default=None,
                    help="write the parity record (JSON) here")
    ap.add_argument("--algorithm", default="sa-s",
                    help="sweep algorithm (sweep mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--max-iterations", type=int, default=2000)
    ap.add_argument("--max-generations", type=int, default=30)
    ap.add_argument("--n-chains", type=int, default=4)
    ap.add_argument("--n-islands", type=int, default=3)
    ap.add_argument("--migration-every", type=int, default=64)
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="iterations/generations (sweep) or barriers "
                         "(portfolio) between snapshots")
    args = ap.parse_args(argv)

    from repro.core import get_problem, pack_portfolio, pack_sweep

    problems = [get_problem(n.strip()) for n in args.problems.split(",")]
    hook = _die_at(args.die_at_checkpoint) if args.die_at_checkpoint else None

    if args.mode == "sweep":
        sweep = pack_sweep(
            problems,
            args.algorithm,
            seed=args.seed,
            max_seconds=_HUGE_SECONDS,
            backend=args.backend,
            checkpoint_dir=args.dir,
            checkpoint_every=args.checkpoint_every or 500,
            resume=args.resume,
            on_checkpoint=hook,
            max_iterations=args.max_iterations,
            max_generations=args.max_generations,
            n_chains=args.n_chains,
            patience=_HUGE_PATIENCE,
        )
        record = {
            "mode": "sweep",
            "algorithm": args.algorithm,
            "candidates": [
                dict(_solution_record(r),
                     trace_costs=[c for _, c in r.trace])
                for r in sweep.results
            ],
        }
        print(sweep.summary())
    else:
        res = pack_portfolio(
            problems[0],
            n_islands=args.n_islands,
            seed=args.seed,
            max_seconds=_HUGE_SECONDS,
            migration_every=args.migration_every,
            backend=args.backend,
            checkpoint_dir=args.dir,
            checkpoint_every=args.checkpoint_every or 1,
            resume=args.resume,
            on_checkpoint=hook,
            max_iterations=args.max_iterations,
            max_generations=args.max_generations,
            patience=_HUGE_PATIENCE,
        )
        record = dict(
            _solution_record(res),
            mode="portfolio",
            barriers=int(res.params["barriers"]),
            migrations=int(res.params["migrations"]),
        )
        print(f"{res.algorithm}: cost={res.cost} "
              f"barriers={res.params['barriers']}")

    if args.out:
        Path(args.out).write_text(json.dumps(record, indent=2))
        print(f"parity record -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
